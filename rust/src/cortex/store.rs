//! Durable session store — the fourth memory tier (hot fp32 → warm int8 →
//! cold host slab → **durable file**), giving sessions a life beyond their
//! TCP connection.  A checkpointed session can be dropped entirely (its
//! permit, ticket and pool blocks released) and later rebuilt bit-identically
//! via `POST /sessions/{id}/resume`; under pool pressure the admission path
//! preempts the coldest parked session to disk instead of shedding a new
//! arrival with 503.  Single embedded file, no external DB dependencies —
//! the "SQLite for agent memory" idiom with the schema cut down to exactly
//! what resume needs.
//!
//! # On-disk format
//!
//! ```text
//! offset 0    ┌───────────────────────────────┐
//!             │ header slot A (32 bytes)      │  magic "WARPSTOR" · generation
//! offset 32   ├───────────────────────────────┤  u64 · committed-tail u64 ·
//!             │ header slot B (32 bytes)      │  crc32 of the first 24 bytes
//! offset 64   ├───────────────────────────────┤
//!             │ record: len u32 · id u64 ·    │  append-only checkpoint log;
//!             │   payload-crc u32 ·           │  payload is the
//!             │   header-crc u32 ·            │  [`SessionCheckpoint`] codec;
//!             │   payload (len bytes)         │  header-crc covers the first
//!             ├───────────────────────────────┤  16 header bytes so the id
//!             │ record …                      │  survives payload corruption
//!             └───────────────────────────────┘
//! ```
//!
//! # Commit protocol (atomic header flip)
//!
//! A checkpoint appends its record at the committed tail, syncs, then
//! writes the **alternate** header slot with `generation + 1` and the new
//! tail, and syncs again.  Recovery takes the highest-generation slot whose
//! CRC validates, so every crash window resolves cleanly:
//!
//! * crash before the record sync — the old header still points below the
//!   torn bytes; they are invisible and the next append overwrites them;
//! * crash mid-header-write — the slot being written fails its CRC and the
//!   other slot (the previous commit) wins;
//! * crash after the header sync — the record is durable and indexed.
//!
//! # Corruption recovery
//!
//! Opening a store scans `[64, committed_tail)` rebuilding the id → record
//! index (the latest record per session id wins; earlier ones count as
//! `superseded`).  The record header carries the session id under its own
//! CRC, separate from the payload CRC, so corruption resolves without
//! resurrecting stale state:
//!
//! * **payload CRC fails, header CRC holds** — the id is still trusted; the
//!   record counts as `corrupt_records_skipped` *and still supersedes* any
//!   earlier record of the same id, so `take` reports that session as
//!   [`StoreError::Unknown`] rather than silently rolling it back to a
//!   superseded checkpoint;
//! * **header CRC fails (or its length is insane)** — nothing after this
//!   point can be framed; the scan ends and the remaining committed region
//!   counts as one corrupt record.  Records indexed *before* the damage
//!   stay resumable (last-good-checkpoint semantics — the only window in
//!   which an earlier checkpoint can be served, bounded by the 20-byte
//!   header as the corruption target).
//!
//! Bytes past the committed tail are a torn append and are ignored without
//! counting.  Corruption is therefore always *contained*: a flipped bit
//! costs exactly the records it touches ([`StoreError::Corrupt`] at resume
//! time), never a panic — and `take` is single-use, so a resumed id cannot
//! be resumed again until it is checkpointed again.
//!
//! # Conservation law
//!
//! Every record this store handle has ever known (`checkpoints`: appended
//! through it, or encountered in the recovery scan) ends in exactly one of
//! four states, which [`SessionStore::check_invariants`] re-proves:
//!
//! ```text
//! checkpoints == resumes + superseded + corrupt_records_skipped + retained
//! ```
//!
//! (The preempt path never mints or destroys records — `preempt_to_disk`
//! drops a *resident* parked ticket whose record is already durable, so it
//! moves nothing across the ledger.)
//!
//! # Locking
//!
//! The store's mutable state sits behind one [`RankedMutex`] at
//! [`LockRank::Registry`] (outermost, process-lifetime registry — the same
//! level as the serve layer's accept queue, which is only ever held as a
//! statement temporary).  Dropping a preempted ticket under the store lock
//! releases prism (`PrismAgents`) and pool (`PoolState`) state, both
//! strictly below `Registry` — acquire-descending holds.  The admission
//! gate reads [`SessionStore::parked_resident`] through an atomic, never
//! the lock: it runs under the scheduler's `SessionTable` lock, which ranks
//! *below* `Registry` and must not acquire upward.

use std::any::Any;
use std::collections::HashMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::sync::{LockRank, RankedMutex};

/// Magic prefix of both header slots.
const MAGIC: &[u8; 8] = b"WARPSTOR";
/// One header slot: magic 8 · generation 8 · tail 8 · crc 4 · pad 4.
const SLOT_BYTES: u64 = 32;
/// Two slots; records start here.
const HEADER_BYTES: u64 = 2 * SLOT_BYTES;
/// Per-record header: len u32 · id u64 · payload-crc u32 · header-crc u32.
const RECORD_HEADER_BYTES: u64 = 20;
/// Hard cap on one record's payload — lengths beyond this are treated as
/// scan-ending corruption, bounding what a flipped length byte can allocate.
pub const MAX_RECORD_BYTES: u32 = 256 * 1024 * 1024;

/// Typed store failures.  `Corrupt` is scoped to the record it names — the
/// store stays serviceable and other records stay resumable.
#[derive(Debug)]
pub enum StoreError {
    /// The record failed its CRC or decode; it has been dropped from the
    /// index (counted in `corrupt_records_skipped`).
    Corrupt(String),
    /// No retained record under this session id (never checkpointed,
    /// already resumed, or lost to corruption).
    Unknown(u64),
    /// A checkpoint payload over [`MAX_RECORD_BYTES`].
    TooLarge(usize),
    /// Underlying file I/O failed.
    Io(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Corrupt(m) => write!(f, "corrupt store record: {m}"),
            StoreError::Unknown(id) => write!(f, "no checkpoint for session {id}"),
            StoreError::TooLarge(n) => {
                write!(f, "checkpoint payload {n} bytes > cap {MAX_RECORD_BYTES}")
            }
            StoreError::Io(m) => write!(f, "store io: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

fn io_err(e: std::io::Error) -> StoreError {
    StoreError::Io(e.to_string())
}

/// Bitwise reflected IEEE CRC-32 (no table — the store is not the hot
/// path, and the 256-entry table would be the only one in the crate).
fn crc32(data: &[u8]) -> u32 {
    let mut crc: u32 = 0xFFFF_FFFF;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ── Checkpoint payload codec ────────────────────────────────────────────

/// Everything resume needs, captured at a commit point: identity, sampler
/// and RNG state, generation progress, and the block-table chain split
/// into the registry-shared prefix (re-attached by hash chain at resume —
/// the shared *bytes* are never re-stored) and the private tail rows
/// (serialized fp32, exactly the `[L, n, row]` layout `append_rows`
/// expects back).
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    /// Durable session id — the scheduler permit id at first open; kept
    /// across resume cycles so the client's handle stays stable.
    pub id: u64,
    /// Sampler RNG position ([`crate::util::XorShift::state`]).
    pub rng_state: u64,
    /// Synapse snapshot version current at checkpoint (informational —
    /// the synapse is shared global state and is not rolled back).
    pub synapse_version: u64,
    /// Tokens generated so far.
    pub generated: u64,
    /// Generation budget.
    pub max_tokens: u64,
    /// Text position (== cache rows at checkpoint).
    pub pos: i64,
    /// Leading rows held *by reference* from the prefix registry; resume
    /// re-attaches them via the content-addressed hash chain.
    pub shared_rows: u32,
    /// Total cache rows; `total_rows - shared_rows` private tail rows ride
    /// in `k_tail`/`v_tail`.
    pub total_rows: u32,
    /// Blocks parked in the cold host slab when the session hibernated
    /// (tier tag — the payload itself is checkpointed hot).
    pub offloaded_blocks: u32,
    /// Original prompt (router re-feed + prefix-chain keys).
    pub prompt: String,
    /// Visible text generated so far (router re-feed + client catch-up).
    pub text: String,
    /// Truncated prompt token ids — the prefix-chain keys.
    pub prompt_ids: Vec<i32>,
    /// Sampler repetition window.
    pub recent: Vec<i32>,
    /// Last logits (next sample draws from these — bit-exact).
    pub logits: Vec<f32>,
    /// Last hidden state (gate evaluation + synapse extraction input).
    pub hidden: Vec<f32>,
    /// Private tail K rows, layer-major `[L, n, row]`.
    pub k_tail: Vec<f32>,
    /// Private tail V rows, layer-major `[L, n, row]`.
    pub v_tail: Vec<f32>,
}

/// Codec version byte leading every payload.
const CODEC_VERSION: u8 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_vec_i32(out: &mut Vec<u8>, v: &[i32]) {
    put_u32(out, v.len() as u32);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for x in v {
        // bit-exact: f32 travels as its IEEE bits, never reformatted
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a record payload.  Every
/// overrun is a typed [`StoreError::Corrupt`], never a panic — the decode
/// path is exactly where flipped bits land.
struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], StoreError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                StoreError::Corrupt(format!(
                    "payload truncated: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len()
                ))
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, StoreError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, StoreError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn i64(&mut self) -> Result<i64, StoreError> {
        Ok(self.u64()? as i64)
    }

    /// Element count for a 4-byte-element vector, pre-validated against
    /// the remaining payload so a corrupt count cannot drive a huge
    /// allocation.
    fn count4(&mut self) -> Result<usize, StoreError> {
        let n = self.u32()? as usize;
        if n.saturating_mul(4) > self.buf.len() - self.pos {
            return Err(StoreError::Corrupt(format!(
                "vector count {n} exceeds remaining payload"
            )));
        }
        Ok(n)
    }

    fn string(&mut self) -> Result<String, StoreError> {
        let n = self.u32()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec())
            .map_err(|_| StoreError::Corrupt("string field is not UTF-8".into()))
    }

    fn vec_i32(&mut self) -> Result<Vec<i32>, StoreError> {
        let n = self.count4()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            v.push(i32::from_le_bytes([b[0], b[1], b[2], b[3]]));
        }
        Ok(v)
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, StoreError> {
        let n = self.count4()?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let b = self.take(4)?;
            v.push(f32::from_bits(u32::from_le_bytes([b[0], b[1], b[2], b[3]])));
        }
        Ok(v)
    }
}

impl SessionCheckpoint {
    /// Serialize to the record payload (little-endian; floats as IEEE
    /// bits, so encode→decode round-trips bit-exactly).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + self.prompt.len()
                + self.text.len()
                + 4 * (self.prompt_ids.len() + self.recent.len())
                + 4 * (self.logits.len()
                    + self.hidden.len()
                    + self.k_tail.len()
                    + self.v_tail.len()),
        );
        out.push(CODEC_VERSION);
        put_u64(&mut out, self.id);
        put_u64(&mut out, self.rng_state);
        put_u64(&mut out, self.synapse_version);
        put_u64(&mut out, self.generated);
        put_u64(&mut out, self.max_tokens);
        put_u64(&mut out, self.pos as u64);
        put_u32(&mut out, self.shared_rows);
        put_u32(&mut out, self.total_rows);
        put_u32(&mut out, self.offloaded_blocks);
        put_str(&mut out, &self.prompt);
        put_str(&mut out, &self.text);
        put_vec_i32(&mut out, &self.prompt_ids);
        put_vec_i32(&mut out, &self.recent);
        put_vec_f32(&mut out, &self.logits);
        put_vec_f32(&mut out, &self.hidden);
        put_vec_f32(&mut out, &self.k_tail);
        put_vec_f32(&mut out, &self.v_tail);
        out
    }

    /// Decode a record payload.  Any truncation, bad count or version
    /// mismatch is [`StoreError::Corrupt`].
    pub fn decode(bytes: &[u8]) -> Result<SessionCheckpoint, StoreError> {
        let mut r = ByteReader { buf: bytes, pos: 0 };
        let version = r.u8()?;
        if version != CODEC_VERSION {
            return Err(StoreError::Corrupt(format!(
                "unknown checkpoint codec version {version}"
            )));
        }
        Ok(SessionCheckpoint {
            id: r.u64()?,
            rng_state: r.u64()?,
            synapse_version: r.u64()?,
            generated: r.u64()?,
            max_tokens: r.u64()?,
            pos: r.i64()?,
            shared_rows: r.u32()?,
            total_rows: r.u32()?,
            offloaded_blocks: r.u32()?,
            prompt: r.string()?,
            text: r.string()?,
            prompt_ids: r.vec_i32()?,
            recent: r.vec_i32()?,
            logits: r.vec_f32()?,
            hidden: r.vec_f32()?,
            k_tail: r.vec_f32()?,
            v_tail: r.vec_f32()?,
        })
    }
}

/// Frame one record: CRC-protected header (so the id survives payload
/// corruption) followed by the payload.
fn encode_record(id: u64, payload: &[u8]) -> Vec<u8> {
    let mut rec = Vec::with_capacity(RECORD_HEADER_BYTES as usize + payload.len());
    put_u32(&mut rec, payload.len() as u32);
    put_u64(&mut rec, id);
    put_u32(&mut rec, crc32(payload));
    let hdr_crc = crc32(&rec[0..16]);
    put_u32(&mut rec, hdr_crc);
    rec.extend_from_slice(payload);
    rec
}

/// Parse a record header at `raw[off..]`: `(len, id, payload_crc)` if the
/// header CRC validates, else `None` (the scan cannot frame past it).
fn decode_record_header(raw: &[u8], off: usize) -> Option<(u32, u64, u32)> {
    let hdr = raw.get(off..off + RECORD_HEADER_BYTES as usize)?;
    let hdr_crc = u32::from_le_bytes([hdr[16], hdr[17], hdr[18], hdr[19]]);
    if crc32(&hdr[0..16]) != hdr_crc {
        return None;
    }
    let len = u32::from_le_bytes([hdr[0], hdr[1], hdr[2], hdr[3]]);
    let mut id = [0u8; 8];
    id.copy_from_slice(&hdr[4..12]);
    let payload_crc = u32::from_le_bytes([hdr[12], hdr[13], hdr[14], hdr[15]]);
    Some((len, u64::from_le_bytes(id), payload_crc))
}

// ── Store gauges ────────────────────────────────────────────────────────

/// Store gauges (the `store` block of `/stats` and `/metrics`).  The
/// ledger counters obey the conservation law re-proved by
/// [`SessionStore::check_invariants`].
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    /// Records known to this handle: appended through it + found live or
    /// corrupt in the recovery scan.
    pub checkpoints: u64,
    /// Records taken for resume (single-use: taking removes the entry).
    pub resumes: u64,
    /// Resident parked tickets dropped to free pool headroom for a new
    /// admission (their records stay durable — this moves nothing on the
    /// record ledger).
    pub preempt_to_disk: u64,
    /// Committed file bytes (header + record log through the tail).
    pub store_bytes: u64,
    /// Records dropped to contained corruption (CRC/decode failure).
    pub corrupt_records_skipped: u64,
    /// Records currently live in the index, resumable.
    pub retained: u64,
    /// Records replaced by a newer checkpoint of the same session id.
    pub superseded: u64,
    /// Parked sessions whose ticket is still resident in memory (the
    /// preempt-to-disk candidates).  Read lock-free by the admission gate.
    pub parked_resident: u64,
}

/// A hibernated session's in-memory remainder: the opaque parked ticket
/// (blocks in the cold host slab) plus its park order for coldest-first
/// preemption.
struct Parked {
    state: Box<dyn Any + Send>,
    seq: u64,
}

struct StoreInner {
    file: File,
    path: PathBuf,
    /// Committed log tail (next append offset).
    tail: u64,
    /// Header generation of the last commit.
    generation: u64,
    /// session id → (record offset, payload length) of the latest record.
    index: HashMap<u64, (u64, u32)>,
    /// Hibernated-but-resident tickets, preemptable to disk.
    resident: HashMap<u64, Parked>,
    next_seq: u64,
}

/// The crash-safe single-file session store.  One per [`super::WarpCortex`]
/// when `CortexConfig::store_path` is set; see the module docs for the
/// format, the commit protocol and the conservation law.
pub struct SessionStore {
    inner: RankedMutex<StoreInner>,
    // Ledger counters live outside the lock so the admission gate (which
    // runs under the scheduler's SessionTable lock) and /stats can read
    // them without acquiring Registry rank.  `stats()` still snapshots
    // under the lock so the conservation law is checked against a
    // consistent cut.
    checkpoints: AtomicU64,
    resumes: AtomicU64,
    preempt_to_disk: AtomicU64,
    store_bytes: AtomicU64,
    corrupt_records_skipped: AtomicU64,
    retained: AtomicU64,
    superseded: AtomicU64,
    parked_resident: AtomicU64,
}

/// What [`SessionStore::take`] hands back: the decoded checkpoint plus, on
/// the fast path, the still-resident parked ticket (downcast by the cortex
/// to its `AgentTicket`).
pub struct ResumeTicket {
    pub checkpoint: SessionCheckpoint,
    pub resident: Option<Box<dyn Any + Send>>,
}

fn encode_slot(generation: u64, tail: u64) -> [u8; SLOT_BYTES as usize] {
    let mut slot = [0u8; SLOT_BYTES as usize];
    slot[0..8].copy_from_slice(MAGIC);
    slot[8..16].copy_from_slice(&generation.to_le_bytes());
    slot[16..24].copy_from_slice(&tail.to_le_bytes());
    let crc = crc32(&slot[0..24]);
    slot[24..28].copy_from_slice(&crc.to_le_bytes());
    slot
}

/// (generation, tail) of a slot if its magic and CRC validate.
fn decode_slot(raw: &[u8]) -> Option<(u64, u64)> {
    if raw.len() < SLOT_BYTES as usize || &raw[0..8] != MAGIC {
        return None;
    }
    let crc = u32::from_le_bytes([raw[24], raw[25], raw[26], raw[27]]);
    if crc32(&raw[0..24]) != crc {
        return None;
    }
    let mut g = [0u8; 8];
    g.copy_from_slice(&raw[8..16]);
    let mut t = [0u8; 8];
    t.copy_from_slice(&raw[16..24]);
    Some((u64::from_le_bytes(g), u64::from_le_bytes(t)))
}

impl SessionStore {
    /// Open (or create) the store at `path`, running the recovery scan.
    /// See the module docs for how torn tails, bad CRCs and insane lengths
    /// are contained; none of them fail the open.
    pub fn open(path: impl AsRef<Path>) -> Result<SessionStore, StoreError> {
        let path = path.as_ref().to_path_buf();
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(&path)
            .map_err(io_err)?;
        let mut raw = Vec::new();
        file.seek(SeekFrom::Start(0)).map_err(io_err)?;
        file.read_to_end(&mut raw).map_err(io_err)?;

        let mut checkpoints = 0u64;
        let mut corrupt = 0u64;
        let mut superseded = 0u64;
        let mut index: HashMap<u64, (u64, u32)> = HashMap::new();

        // Highest-generation valid header slot wins; neither valid means a
        // fresh (or non-store) file — initialize generation 0 / empty log.
        // The double-write protocol guarantees a real store always keeps
        // at least one valid slot, so reinitialization cannot orphan data.
        let slot_a = decode_slot(&raw);
        let slot_b = decode_slot(raw.get(SLOT_BYTES as usize..).unwrap_or(&[]));
        let (generation, tail) = match (slot_a, slot_b) {
            (Some(a), Some(b)) => {
                if a.0 >= b.0 {
                    a
                } else {
                    b
                }
            }
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (None, None) => {
                file.set_len(0).map_err(io_err)?;
                file.seek(SeekFrom::Start(0)).map_err(io_err)?;
                file.write_all(&encode_slot(0, HEADER_BYTES)).map_err(io_err)?;
                file.write_all(&[0u8; SLOT_BYTES as usize]).map_err(io_err)?;
                file.sync_data().map_err(io_err)?;
                (0, HEADER_BYTES)
            }
        };
        let tail = tail.max(HEADER_BYTES);

        // Recovery scan over the committed region.  Latest record per id
        // wins; earlier ones are superseded — including when the latest is
        // corrupt (its CRC-protected header still names the id), so
        // corruption never rolls a session back to a superseded record.
        // An unframeable remainder counts as one corrupt record so the
        // conservation ledger still balances.
        let scan_end = tail.min(raw.len() as u64) as usize;
        let mut off = HEADER_BYTES as usize;
        loop {
            if off + RECORD_HEADER_BYTES as usize > scan_end {
                break;
            }
            let (len, id, payload_crc) = match decode_record_header(&raw, off) {
                Some(h) => h,
                None => break,
            };
            let start = off + RECORD_HEADER_BYTES as usize;
            let end = start + len as usize;
            if len == 0 || len > MAX_RECORD_BYTES || end > scan_end {
                break;
            }
            checkpoints += 1;
            if index.remove(&id).is_some() {
                superseded += 1;
            }
            let payload = &raw[start..end];
            if crc32(payload) == payload_crc {
                index.insert(id, (off as u64, len));
            } else {
                corrupt += 1;
            }
            off = end;
        }
        if (off as u64) < tail {
            // Committed bytes the scan could not parse into records — one
            // corrupt pseudo-record covers the whole region.
            checkpoints += 1;
            corrupt += 1;
        }

        let retained = index.len() as u64;
        Ok(SessionStore {
            inner: RankedMutex::new(
                LockRank::Registry,
                StoreInner {
                    file,
                    path,
                    tail,
                    generation,
                    index,
                    resident: HashMap::new(),
                    next_seq: 0,
                },
            ),
            checkpoints: AtomicU64::new(checkpoints),
            resumes: AtomicU64::new(0),
            preempt_to_disk: AtomicU64::new(0),
            store_bytes: AtomicU64::new(tail),
            corrupt_records_skipped: AtomicU64::new(corrupt),
            retained: AtomicU64::new(retained),
            superseded: AtomicU64::new(superseded),
            parked_resident: AtomicU64::new(0),
        })
    }

    /// The backing file's path.
    pub fn path(&self) -> PathBuf {
        self.inner.lock().path.clone()
    }

    /// Append + commit one checkpoint.  A later checkpoint of the same id
    /// supersedes the earlier record (the log is append-only; the index
    /// moves).
    pub fn checkpoint(&self, cp: &SessionCheckpoint) -> Result<(), StoreError> {
        let payload = cp.encode();
        if payload.len() as u64 > MAX_RECORD_BYTES as u64 {
            return Err(StoreError::TooLarge(payload.len()));
        }
        let mut inner = self.inner.lock();
        let off = inner.tail;
        let rec = encode_record(cp.id, &payload);
        inner.file.seek(SeekFrom::Start(off)).map_err(io_err)?;
        inner.file.write_all(&rec).map_err(io_err)?;
        inner.file.sync_data().map_err(io_err)?;
        // Record durable — flip the alternate header slot to commit it.
        let new_tail = off + rec.len() as u64;
        let generation = inner.generation + 1;
        let slot_off = (generation % 2) * SLOT_BYTES;
        inner.file.seek(SeekFrom::Start(slot_off)).map_err(io_err)?;
        inner
            .file
            .write_all(&encode_slot(generation, new_tail))
            .map_err(io_err)?;
        inner.file.sync_data().map_err(io_err)?;
        inner.generation = generation;
        inner.tail = new_tail;
        let replaced = inner.index.insert(cp.id, (off, payload.len() as u32));
        if replaced.is_some() {
            self.superseded.fetch_add(1, Ordering::Relaxed);
        }
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        self.retained.store(inner.index.len() as u64, Ordering::Relaxed);
        self.store_bytes.store(inner.tail, Ordering::Relaxed);
        Ok(())
    }

    /// Take the retained record for `id` (single-use: the index entry is
    /// removed — resuming again requires checkpointing again), along with
    /// the still-resident parked ticket if the session hibernated in this
    /// process.  A CRC or decode failure drops the record as corrupt and
    /// surfaces [`StoreError::Corrupt`]; other records are unaffected.
    pub fn take(&self, id: u64) -> Result<ResumeTicket, StoreError> {
        let mut inner = self.inner.lock();
        let (off, len) = match inner.index.get(&id) {
            Some(&e) => e,
            None => return Err(StoreError::Unknown(id)),
        };
        let resident = inner.resident.remove(&id).map(|p| p.state);
        self.parked_resident
            .store(inner.resident.len() as u64, Ordering::Relaxed);
        let mut payload = vec![0u8; len as usize];
        let read = (|| -> Result<u32, StoreError> {
            inner.file.seek(SeekFrom::Start(off + 12)).map_err(io_err)?;
            let mut crc = [0u8; 4];
            inner.file.read_exact(&mut crc).map_err(io_err)?;
            inner
                .file
                .seek(SeekFrom::Start(off + RECORD_HEADER_BYTES))
                .map_err(io_err)?;
            inner.file.read_exact(&mut payload).map_err(io_err)?;
            Ok(u32::from_le_bytes(crc))
        })();
        let outcome = read.and_then(|crc| {
            if crc32(&payload) != crc {
                return Err(StoreError::Corrupt(format!(
                    "record for session {id} failed its CRC"
                )));
            }
            let cp = SessionCheckpoint::decode(&payload)?;
            if cp.id != id {
                return Err(StoreError::Corrupt(format!(
                    "record indexed under {id} decodes to session {}",
                    cp.id
                )));
            }
            Ok(cp)
        });
        inner.index.remove(&id);
        self.retained.store(inner.index.len() as u64, Ordering::Relaxed);
        match outcome {
            Ok(checkpoint) => {
                self.resumes.fetch_add(1, Ordering::Relaxed);
                Ok(ResumeTicket {
                    checkpoint,
                    resident,
                })
            }
            Err(e) => {
                // The record (and any resident ticket that depended on it)
                // is lost to contained corruption; the ledger moves it
                // from retained to corrupt_records_skipped.
                self.corrupt_records_skipped.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Register a hibernated session's still-resident parked ticket (kept
    /// opaque so the store stays host-testable without a prism).  Resident
    /// tickets make resume a page-in instead of a rebuild — and are what
    /// [`SessionStore::preempt_coldest`] sacrifices under pool pressure.
    pub fn park_resident(&self, id: u64, state: Box<dyn Any + Send>) {
        let mut inner = self.inner.lock();
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.resident.insert(id, Parked { state, seq });
        self.parked_resident
            .store(inner.resident.len() as u64, Ordering::Relaxed);
    }

    /// Drop the coldest (earliest-parked) resident ticket whose record is
    /// durable, releasing its pool blocks so a new admission fits — the
    /// preempt-to-disk path.  Returns whether a ticket was dropped.
    /// Resident entries without a durable record are never preempted
    /// (dropping them would lose state, not tier it).
    pub fn preempt_coldest(&self) -> bool {
        let mut inner = self.inner.lock();
        let victim = inner
            .resident
            .iter()
            .filter(|e| inner.index.contains_key(e.0))
            .min_by_key(|e| e.1.seq)
            .map(|e| *e.0);
        match victim {
            Some(id) => {
                // Dropping the ticket under the store lock releases prism
                // + pool state — both rank below Registry (descending).
                inner.resident.remove(&id);
                self.preempt_to_disk.fetch_add(1, Ordering::Relaxed);
                self.parked_resident
                    .store(inner.resident.len() as u64, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Resident parked tickets — lock-free, safe for the admission gate
    /// (which runs under the scheduler's SessionTable lock).
    pub fn parked_resident(&self) -> u64 {
        self.parked_resident.load(Ordering::Relaxed)
    }

    /// Gauge snapshot, taken under the store lock so the counters form a
    /// consistent cut (the lock-free atomics alone could be read mid-
    /// checkpoint and transiently violate the conservation law).
    pub fn stats(&self) -> StoreStats {
        let _inner = self.inner.lock();
        StoreStats {
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
            resumes: self.resumes.load(Ordering::Relaxed),
            preempt_to_disk: self.preempt_to_disk.load(Ordering::Relaxed),
            store_bytes: self.store_bytes.load(Ordering::Relaxed),
            corrupt_records_skipped: self.corrupt_records_skipped.load(Ordering::Relaxed),
            retained: self.retained.load(Ordering::Relaxed),
            superseded: self.superseded.load(Ordering::Relaxed),
            parked_resident: self.parked_resident.load(Ordering::Relaxed),
        }
    }

    /// Re-prove the store conservation law: every record ever known
    /// (`checkpoints`) is exactly one of resumed (`resumes`), replaced
    /// (`superseded`), lost to contained corruption
    /// (`corrupt_records_skipped`) or still resumable (`retained`).  Also
    /// sanity-checks the byte ledger (`store_bytes` covers at least the
    /// header) and the preempt gauges (`preempt_to_disk` never exceeds
    /// what was ever resident: parks = current `parked_resident` +
    /// preempted + resumed-or-taken residents).
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let s = self.stats();
        let accounted = s.resumes + s.superseded + s.corrupt_records_skipped + s.retained;
        if s.checkpoints != accounted {
            return Err(format!(
                "store conservation violated: checkpoints {} != resumes {} + superseded {} \
                 + corrupt_records_skipped {} + retained {}",
                s.checkpoints, s.resumes, s.superseded, s.corrupt_records_skipped, s.retained
            ));
        }
        if s.store_bytes < HEADER_BYTES {
            return Err(format!(
                "store_bytes {} below the {HEADER_BYTES}-byte header",
                s.store_bytes
            ));
        }
        let inner = self.inner.lock();
        if s.parked_resident != inner.resident.len() as u64 {
            return Err(format!(
                "parked_resident gauge {} != resident map {} (preempt_to_disk {})",
                s.parked_resident,
                inner.resident.len(),
                s.preempt_to_disk
            ));
        }
        if s.retained != inner.index.len() as u64 {
            return Err(format!(
                "retained gauge {} != index {}",
                s.retained,
                inner.index.len()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("warpstore_{}_{tag}.wst", std::process::id()))
    }

    fn cp(id: u64, salt: u64) -> SessionCheckpoint {
        SessionCheckpoint {
            id,
            rng_state: 0x9E37 ^ salt,
            synapse_version: salt,
            generated: 3 + salt,
            max_tokens: 64,
            pos: 7 + salt as i64,
            shared_rows: 4,
            total_rows: 9,
            offloaded_blocks: 1,
            prompt: format!("prompt-{id}-{salt}"),
            text: "abc".into(),
            prompt_ids: vec![1, 2, 3, -4],
            recent: vec![5, 6],
            logits: vec![0.5, -1.25, f32::MIN_POSITIVE, salt as f32],
            hidden: vec![1.0, 2.0],
            k_tail: vec![0.125; 8],
            v_tail: vec![-0.125; 8],
        }
    }

    fn open_fresh(tag: &str) -> (SessionStore, PathBuf) {
        let path = tmp_path(tag);
        let _ = std::fs::remove_file(&path);
        (SessionStore::open(&path).unwrap(), path)
    }

    #[test]
    fn codec_roundtrips_bit_exactly() {
        let a = cp(42, 7);
        let bytes = a.encode();
        let b = SessionCheckpoint::decode(&bytes).unwrap();
        // byte-level equality implies bit-exact floats (encode stores
        // IEEE bits verbatim)
        assert_eq!(bytes, b.encode());
        assert_eq!(b.id, 42);
        assert_eq!(b.prompt, "prompt-42-7");
        assert_eq!(b.logits.len(), 4);
        assert_eq!(b.logits[2].to_bits(), f32::MIN_POSITIVE.to_bits());
    }

    #[test]
    fn decode_rejects_truncation_without_panicking() {
        let bytes = cp(1, 1).encode();
        for cut in 0..bytes.len() {
            match SessionCheckpoint::decode(&bytes[..cut]) {
                Err(StoreError::Corrupt(_)) => {}
                Ok(_) => panic!("decode of a {cut}-byte truncation succeeded"),
                Err(e) => panic!("unexpected error on truncation: {e}"),
            }
        }
    }

    #[test]
    fn checkpoint_take_roundtrip_and_single_use() {
        let (store, path) = open_fresh("roundtrip");
        let a = cp(10, 1);
        store.checkpoint(&a).unwrap();
        store.check_invariants().unwrap();
        let got = store.take(10).unwrap();
        assert_eq!(got.checkpoint.encode(), a.encode());
        assert!(got.resident.is_none());
        // single-use: the record is consumed
        assert!(matches!(store.take(10), Err(StoreError::Unknown(10))));
        let s = store.stats();
        assert_eq!((s.checkpoints, s.resumes, s.retained), (1, 1, 0));
        store.check_invariants().unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn recovery_rebuilds_index_latest_record_wins() {
        let (store, path) = open_fresh("recover");
        store.checkpoint(&cp(1, 1)).unwrap();
        store.checkpoint(&cp(2, 1)).unwrap();
        let latest = cp(1, 9); // supersedes the first record for id 1
        store.checkpoint(&latest).unwrap();
        assert_eq!(store.stats().superseded, 1);
        drop(store);

        let store = SessionStore::open(&path).unwrap();
        let s = store.stats();
        assert_eq!(s.checkpoints, 3, "all scanned records counted");
        assert_eq!(s.superseded, 1);
        assert_eq!(s.retained, 2);
        assert_eq!(s.corrupt_records_skipped, 0);
        store.check_invariants().unwrap();
        assert_eq!(store.take(1).unwrap().checkpoint.encode(), latest.encode());
        assert_eq!(store.take(2).unwrap().checkpoint.generated, cp(2, 1).generated);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn torn_trailing_append_is_invisible_after_reopen() {
        let (store, path) = open_fresh("torn");
        store.checkpoint(&cp(5, 2)).unwrap();
        drop(store);
        // Simulate a crash mid-append: record bytes land past the
        // committed tail but the header never flipped.
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0xAB; 37]).unwrap();
        drop(f);

        let store = SessionStore::open(&path).unwrap();
        let s = store.stats();
        assert_eq!(s.checkpoints, 1, "torn bytes are not records");
        assert_eq!(s.corrupt_records_skipped, 0);
        assert_eq!(s.retained, 1);
        store.check_invariants().unwrap();
        // the surviving record resumes bit-identically
        assert_eq!(store.take(5).unwrap().checkpoint.encode(), cp(5, 2).encode());
        // and the next append overwrites the torn region cleanly
        store.checkpoint(&cp(6, 3)).unwrap();
        drop(store);
        let store = SessionStore::open(&path).unwrap();
        assert_eq!(store.take(6).unwrap().checkpoint.encode(), cp(6, 3).encode());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bit_flip_corrupts_exactly_one_record() {
        let (store, path) = open_fresh("bitflip");
        store.checkpoint(&cp(1, 1)).unwrap();
        store.checkpoint(&cp(2, 2)).unwrap();
        // flip one payload byte of record 1 (its extent via the index)
        let (off, _len) = *store.inner.lock().index.get(&1).unwrap();
        let at = off + RECORD_HEADER_BYTES + 12;
        drop(store);
        let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(at)).unwrap();
        let mut b = [0u8; 1];
        f.read_exact(&mut b).unwrap();
        f.seek(SeekFrom::Start(at)).unwrap();
        f.write_all(&[b[0] ^ 0x40]).unwrap();
        drop(f);

        let store = SessionStore::open(&path).unwrap();
        let s = store.stats();
        assert_eq!(s.checkpoints, 2);
        assert_eq!(s.corrupt_records_skipped, 1, "only the flipped record");
        assert_eq!(s.retained, 1);
        store.check_invariants().unwrap();
        assert!(matches!(store.take(1), Err(StoreError::Unknown(1))));
        assert_eq!(store.take(2).unwrap().checkpoint.encode(), cp(2, 2).encode());
        store.check_invariants().unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn corrupting_latest_record_never_resumes_the_superseded_one() {
        let (store, path) = open_fresh("stale");
        store.checkpoint(&cp(4, 1)).unwrap(); // superseded
        store.checkpoint(&cp(4, 2)).unwrap(); // latest — about to be flipped
        let (off, _) = *store.inner.lock().index.get(&4).unwrap();
        drop(store);
        let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(off + RECORD_HEADER_BYTES + 3)).unwrap();
        let mut b = [0u8; 1];
        f.read_exact(&mut b).unwrap();
        f.seek(SeekFrom::Start(off + RECORD_HEADER_BYTES + 3)).unwrap();
        f.write_all(&[b[0] ^ 0x01]).unwrap();
        drop(f);

        // The corrupt latest record's CRC-protected header still names the
        // session, so the scan poisons the id rather than re-indexing the
        // superseded record: resume must be Unknown, never stale state.
        let store = SessionStore::open(&path).unwrap();
        assert!(matches!(store.take(4), Err(StoreError::Unknown(4))));
        let s = store.stats();
        assert_eq!(s.checkpoints, 2);
        assert_eq!(s.superseded, 1);
        assert_eq!(s.corrupt_records_skipped, 1);
        assert_eq!(s.retained, 0);
        store.check_invariants().unwrap();
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn insane_length_ends_the_scan_as_contained_corruption() {
        let (store, path) = open_fresh("insane");
        store.checkpoint(&cp(1, 1)).unwrap();
        store.checkpoint(&cp(2, 2)).unwrap();
        let (off, _) = *store.inner.lock().index.get(&2).unwrap();
        drop(store);
        // overwrite record 2's length with garbage past MAX_RECORD_BYTES
        // (also invalidates its header CRC — either way, nothing after
        // this point can be framed)
        let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(off)).unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap();
        drop(f);

        let store = SessionStore::open(&path).unwrap();
        let s = store.stats();
        // record 1 scanned fine; the unparseable committed remainder is
        // one corrupt pseudo-record
        assert_eq!(s.checkpoints, 2);
        assert_eq!(s.corrupt_records_skipped, 1);
        assert_eq!(s.retained, 1);
        store.check_invariants().unwrap();
        assert_eq!(store.take(1).unwrap().checkpoint.encode(), cp(1, 1).encode());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn header_slot_corruption_falls_back_to_the_other_slot() {
        let (store, path) = open_fresh("slots");
        store.checkpoint(&cp(1, 1)).unwrap(); // gen 1 → slot B
        store.checkpoint(&cp(2, 2)).unwrap(); // gen 2 → slot A
        drop(store);
        // Crash mid-write of the *next* commit's slot (gen 3 → slot B):
        // garbage in slot B must fall back to gen 2 in slot A.
        let mut f = OpenOptions::new().read(true).write(true).open(&path).unwrap();
        f.seek(SeekFrom::Start(SLOT_BYTES)).unwrap();
        f.write_all(&[0xCC; SLOT_BYTES as usize]).unwrap();
        drop(f);
        let store = SessionStore::open(&path).unwrap();
        assert_eq!(store.stats().retained, 2, "slot-A commit still visible");
        assert_eq!(store.take(2).unwrap().checkpoint.encode(), cp(2, 2).encode());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn preempt_drops_coldest_resident_with_a_durable_record() {
        let (store, path) = open_fresh("preempt");
        // id 1 parks first (coldest), then id 2; id 3 is resident but has
        // no durable record and must never be preempted.
        store.checkpoint(&cp(1, 1)).unwrap();
        store.checkpoint(&cp(2, 2)).unwrap();
        store.park_resident(1, Box::new("ticket-1".to_string()));
        store.park_resident(2, Box::new("ticket-2".to_string()));
        store.park_resident(3, Box::new("ticket-3".to_string()));
        assert_eq!(store.parked_resident(), 3);

        assert!(store.preempt_coldest());
        assert_eq!(store.parked_resident(), 2);
        assert!(store.preempt_coldest());
        assert_eq!(store.parked_resident(), 1);
        // only the record-less resident remains — not preemptable
        assert!(!store.preempt_coldest());
        assert_eq!(store.stats().preempt_to_disk, 2);
        store.check_invariants().unwrap();

        // the preempted sessions remain resumable from disk (slow path)
        let r = store.take(1).unwrap();
        assert!(r.resident.is_none(), "ticket was preempted");
        assert_eq!(r.checkpoint.encode(), cp(1, 1).encode());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn take_returns_the_resident_ticket_on_the_fast_path() {
        let (store, path) = open_fresh("resident");
        store.checkpoint(&cp(7, 1)).unwrap();
        store.park_resident(7, Box::new(1234u32));
        let r = store.take(7).unwrap();
        let ticket = r.resident.expect("resident fast path");
        assert_eq!(*ticket.downcast::<u32>().unwrap(), 1234);
        assert_eq!(store.parked_resident(), 0);
        store.check_invariants().unwrap();
        let _ = std::fs::remove_file(path);
    }

    /// Crash-safety proptest: random checkpoint / take / reopen / torn-
    /// append / bit-flip interleavings must track a mirror model exactly —
    /// every id either resumes bit-identically or surfaces a typed
    /// `StoreError` for that record only; no panics, no stale state.
    #[test]
    fn crash_safety_random_interleavings() {
        check("store crash safety", 25, |g| {
            let path = tmp_path(&format!("prop{}", g.case));
            let _ = std::fs::remove_file(&path);
            let mut store = SessionStore::open(&path).map_err(|e| e.to_string())?;
            // mirror: id → encoded payload expected on resume
            let mut mirror: HashMap<u64, Vec<u8>> = HashMap::new();
            let mut salt = 0u64;
            for _ in 0..g.usize_in(5..40) {
                match g.usize_in(0..6) {
                    // checkpoint (possibly superseding)
                    0 | 1 => {
                        let id = g.usize_in(1..6) as u64;
                        salt += 1;
                        let c = cp(id, salt);
                        store.checkpoint(&c).map_err(|e| e.to_string())?;
                        mirror.insert(id, c.encode());
                    }
                    // take: must match the mirror bit-exactly, or Unknown
                    2 => {
                        let id = g.usize_in(1..6) as u64;
                        match (store.take(id), mirror.remove(&id)) {
                            (Ok(r), Some(want)) => {
                                crate::prop_assert!(
                                    r.checkpoint.encode() == want,
                                    "resume of {id} not bit-identical"
                                );
                            }
                            (Err(StoreError::Unknown(_)), None) => {}
                            (Ok(_), None) => {
                                return Err(format!("id {id} resurrected from nothing"))
                            }
                            (Err(e), want) => {
                                return Err(format!(
                                    "take({id}) → {e} (mirror had record: {})",
                                    want.is_some()
                                ))
                            }
                        }
                    }
                    // clean restart
                    3 => {
                        drop(store);
                        store = SessionStore::open(&path).map_err(|e| e.to_string())?;
                    }
                    // crash mid-append: torn bytes past the committed tail
                    4 => {
                        drop(store);
                        let n = g.usize_in(1..50);
                        let mut f = OpenOptions::new()
                            .append(true)
                            .open(&path)
                            .map_err(|e| e.to_string())?;
                        f.write_all(&vec![0x5A; n]).map_err(|e| e.to_string())?;
                        drop(f);
                        store = SessionStore::open(&path).map_err(|e| e.to_string())?;
                    }
                    // bit flip inside a known record's payload: that id (and
                    // only that id) becomes Unknown-or-Corrupt
                    _ => {
                        let victim = {
                            let inner = store.inner.lock();
                            inner.index.iter().map(|(id, e)| (*id, *e)).next()
                        };
                        if let Some((id, (off, len))) = victim {
                            drop(store);
                            let at =
                                off + RECORD_HEADER_BYTES + g.usize_in(0..len as usize) as u64;
                            let mut f = OpenOptions::new()
                                .read(true)
                                .write(true)
                                .open(&path)
                                .map_err(|e| e.to_string())?;
                            f.seek(SeekFrom::Start(at)).map_err(|e| e.to_string())?;
                            let mut b = [0u8; 1];
                            f.read_exact(&mut b).map_err(|e| e.to_string())?;
                            f.seek(SeekFrom::Start(at)).map_err(|e| e.to_string())?;
                            f.write_all(&[b[0] ^ (1 << g.usize_in(0..8))])
                                .map_err(|e| e.to_string())?;
                            drop(f);
                            store = SessionStore::open(&path).map_err(|e| e.to_string())?;
                            // the flipped record's header still names the id,
                            // so resume is typed-unavailable for that session
                            // only — never a panic, never the superseded
                            // record's stale bytes
                            match store.take(id) {
                                Err(StoreError::Unknown(_)) | Err(StoreError::Corrupt(_)) => {}
                                Ok(_) => {
                                    return Err(format!(
                                        "flipped record for {id} resumed anyway"
                                    ))
                                }
                                Err(e) => return Err(format!("take after flip: {e}")),
                            }
                            mirror.remove(&id);
                        }
                    }
                }
                store.check_invariants()?;
            }
            // drain: every surviving mirror entry resumes bit-identically
            for (id, want) in mirror {
                let got = store.take(id).map_err(|e| format!("drain {id}: {e}"))?;
                crate::prop_assert!(
                    got.checkpoint.encode() == want,
                    "drained resume of {id} not bit-identical"
                );
            }
            store.check_invariants()?;
            let _ = std::fs::remove_file(&path);
            Ok(())
        });
    }

    #[test]
    fn conservation_law_holds_across_every_transition() {
        let (store, path) = open_fresh("ledger");
        for i in 0..6u64 {
            store.checkpoint(&cp(i % 3, i)).unwrap(); // 3 supersessions
            store.check_invariants().unwrap();
        }
        store.take(0).unwrap();
        store.take(1).unwrap();
        assert!(matches!(store.take(99), Err(StoreError::Unknown(99))));
        let s = store.stats();
        assert_eq!(s.checkpoints, 6);
        assert_eq!(s.superseded, 3);
        assert_eq!(s.resumes, 2);
        assert_eq!(s.retained, 1);
        assert_eq!(s.corrupt_records_skipped, 0);
        assert!(s.store_bytes > HEADER_BYTES);
        store.check_invariants().unwrap();
        let _ = std::fs::remove_file(path);
    }
}
