//! Memory accounting: the measured side of Table 2 and the analytic side of
//! Table 1.
//!
//! [`MemoryTracker`] counts every byte of model state the coordinator
//! actually allocates (weights resident on the device, per-agent KV caches,
//! the shared synapse buffer), categorised so the benches can print the
//! paper's component rows.  Since the paged-KV refactor, the per-agent KV
//! charge is *resident-block bytes*: each cache carries a [`MemGuard`] that
//! the cache resizes as it rents and releases pool blocks, so `MainKv` /
//! `SideKv` track actual fill rather than configured capacity (the pool's
//! own gauges — blocks live, high-water, fragmentation — live on
//! [`crate::model::PoolStats`]).  [`MemoryModel`] projects the same
//! arithmetic onto arbitrary configs — in particular Qwen2.5-0.5B on a
//! 24 GB RTX 4090, the paper's testbed (DESIGN.md §4 records the
//! substitution).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use crate::runtime::ModelConfig;

/// Memory category (the component rows of Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemKind {
    /// Model weights — allocated once (the Prism).
    Weights = 0,
    /// Main-agent KV caches.
    MainKv = 1,
    /// Side-agent KV caches.
    SideKv = 2,
    /// The shared Topological Synapse landmark buffer.
    Synapse = 3,
    /// Fixed per-agent runtime overhead (allocator granularity, activation
    /// workspace) — modelled, not measured, on this CPU substrate.
    Overhead = 4,
    /// Device-resident KV block copies (the pool's device slab).  Counted
    /// separately from the host-side `MainKv`/`SideKv` charges because both
    /// copies are genuinely resident: the host rows are the source of
    /// truth, the device copies are what decode attention actually reads.
    DeviceKv = 5,
    /// Blocks registered in the pool's content-addressed prefix registry.
    /// A shared block is charged here exactly once, however many agent
    /// caches reference it — `MainKv`/`SideKv` count only each cache's
    /// *private* blocks, so Table 2 never multiply-counts a shared prefix.
    SharedKv = 6,
    /// KV payloads offloaded to the pool's cold host slab (parked sessions
    /// and cold registry entries paged out of device memory).  Host RAM,
    /// not VRAM — tracked so every physical byte of KV state is counted
    /// exactly once in its tier: a block's bytes move between
    /// `MainKv`/`SideKv`/`SharedKv`/`DeviceKv` and `HostKv` as it pages
    /// out and back in, never appearing in both.
    HostKv = 7,
}

pub const MEM_KINDS: [MemKind; 8] = [
    MemKind::Weights,
    MemKind::MainKv,
    MemKind::SideKv,
    MemKind::Synapse,
    MemKind::Overhead,
    MemKind::DeviceKv,
    MemKind::SharedKv,
    MemKind::HostKv,
];

impl MemKind {
    pub fn name(&self) -> &'static str {
        match self {
            MemKind::Weights => "weights",
            MemKind::MainKv => "main_kv",
            MemKind::SideKv => "side_kv",
            MemKind::Synapse => "synapse",
            MemKind::Overhead => "overhead",
            MemKind::DeviceKv => "device_kv",
            MemKind::SharedKv => "shared_kv",
            MemKind::HostKv => "host_kv",
        }
    }
}

/// Live byte accounting, by category.
#[derive(Debug, Default)]
pub struct MemoryTracker {
    live: [AtomicI64; 8],
    peak: [AtomicI64; 8],
    allocs: AtomicU64,
    frees: AtomicU64,
}

impl MemoryTracker {
    pub fn new() -> Arc<MemoryTracker> {
        Arc::new(MemoryTracker::default())
    }

    pub fn alloc(self: &Arc<Self>, kind: MemKind, bytes: u64) -> MemGuard {
        let idx = kind as usize;
        let now = self.live[idx].fetch_add(bytes as i64, Ordering::Relaxed) + bytes as i64;
        self.peak[idx].fetch_max(now, Ordering::Relaxed);
        self.allocs.fetch_add(1, Ordering::Relaxed);
        MemGuard {
            tracker: self.clone(),
            kind,
            bytes,
        }
    }

    fn free(&self, kind: MemKind, bytes: u64) {
        self.live[kind as usize].fetch_sub(bytes as i64, Ordering::Relaxed);
        self.frees.fetch_add(1, Ordering::Relaxed);
    }

    pub fn live_bytes(&self, kind: MemKind) -> i64 {
        self.live[kind as usize].load(Ordering::Relaxed)
    }

    pub fn total_live(&self) -> i64 {
        MEM_KINDS.iter().map(|k| self.live_bytes(*k)).sum()
    }

    pub fn snapshot(&self) -> MemSnapshot {
        let mut per = [0i64; 8];
        let mut peak = [0i64; 8];
        for (i, _) in MEM_KINDS.iter().enumerate() {
            per[i] = self.live[i].load(Ordering::Relaxed);
            peak[i] = self.peak[i].load(Ordering::Relaxed);
        }
        MemSnapshot {
            per_kind: per,
            peak_per_kind: peak,
            allocs: self.allocs.load(Ordering::Relaxed),
            frees: self.frees.load(Ordering::Relaxed),
        }
    }
}

/// RAII guard: frees its bytes when dropped (conservation by construction).
#[derive(Debug)]
pub struct MemGuard {
    tracker: Arc<MemoryTracker>,
    kind: MemKind,
    bytes: u64,
}

impl MemGuard {
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Adjust the guarded size (e.g. synapse buffer replaced).
    pub fn resize(&mut self, new_bytes: u64) {
        self.tracker.free(self.kind, self.bytes);
        let idx = self.kind as usize;
        let now = self.tracker.live[idx].fetch_add(new_bytes as i64, Ordering::Relaxed)
            + new_bytes as i64;
        self.tracker.peak[idx].fetch_max(now, Ordering::Relaxed);
        self.bytes = new_bytes;
    }
}

impl Drop for MemGuard {
    fn drop(&mut self) {
        self.tracker.free(self.kind, self.bytes);
    }
}

#[derive(Debug, Clone)]
pub struct MemSnapshot {
    pub per_kind: [i64; 8],
    pub peak_per_kind: [i64; 8],
    pub allocs: u64,
    pub frees: u64,
}

impl MemSnapshot {
    pub fn total(&self) -> i64 {
        self.per_kind.iter().sum()
    }

    pub fn get(&self, kind: MemKind) -> i64 {
        self.per_kind[kind as usize]
    }
}

pub fn fmt_bytes(b: f64) -> String {
    let b = b.max(0.0);
    if b >= 1e9 {
        format!("{:.2} GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2} MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1} kB", b / 1e3)
    } else {
        format!("{b:.0} B")
    }
}

// ── Analytic projection (Table 1 / Table 2 at paper scale) ──────────────

/// Analytic VRAM model for an arbitrary (config, hardware) pair.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub config_name: String,
    /// KV bytes for one cached row (all layers, K+V).
    pub kv_row_bytes: u64,
    /// KV bytes for one cached row in the warm int8 tier: int8 values plus
    /// one fp32 scale per (layer, K/V) row — the `KvPool` quantized-block
    /// layout projected to paper scale.
    pub kv_row_bytes_q8: u64,
    pub weight_bytes: u64,
    /// Full context length L of the standard architecture.
    pub full_ctx: usize,
    /// Landmark count k of the synapse (paper §3.3).
    pub synapse_k: usize,
    /// Side-agent generation budget rows on top of the landmarks.
    pub side_gen: usize,
    /// Fixed per-agent runtime overhead.  The paper measures ~13 MB/agent
    /// total with a ~0.8 MB synapse; the remainder is CUDA allocator
    /// granularity + per-stream activation workspace.  Calibrated to the
    /// paper's Table-2 midpoint (12 MiB).
    pub per_agent_overhead: u64,
    /// Total device memory budget.
    pub vram_total: u64,
    /// Non-model reserved bytes (CUDA context, fragmentation reserve).
    pub vram_reserved: u64,
}

pub const GIB: u64 = 1 << 30;
pub const MIB: u64 = 1 << 20;

impl MemoryModel {
    /// The paper's testbed: Qwen2.5-0.5B (fp16) on an RTX 4090 (24 GB),
    /// 32k full context, k = 64 landmarks.
    pub fn qwen05b_on_4090(cfg: &ModelConfig) -> MemoryModel {
        MemoryModel {
            config_name: cfg.name.clone(),
            kv_row_bytes: cfg.kv_row_bytes(2), // fp16 cache
            kv_row_bytes_q8: cfg.kv_row_bytes(1) + cfg.n_layers as u64 * 8,
            // fp16 weights + embeddings ≈ paper's 1.2 GB figure
            weight_bytes: cfg.weight_bytes(2) + 200 * MIB,
            full_ctx: 32_768,
            synapse_k: 64,
            side_gen: 32,
            per_agent_overhead: 12 * MIB,
            vram_total: 24 * GIB,
            vram_reserved: 1 * GIB,
        }
    }

    /// Model for one of our runnable configs (f32, measured capacities).
    pub fn runnable(cfg: &ModelConfig, main_ctx: usize, synapse_k: usize, side_ctx: usize) -> MemoryModel {
        MemoryModel {
            config_name: cfg.name.clone(),
            kv_row_bytes: cfg.kv_row_bytes(4),
            kv_row_bytes_q8: cfg.kv_row_bytes(1) + cfg.n_layers as u64 * 8,
            weight_bytes: cfg.weight_bytes(4),
            full_ctx: main_ctx,
            synapse_k,
            side_gen: side_ctx.saturating_sub(synapse_k),
            per_agent_overhead: 0, // measured directly on this substrate
            vram_total: 24 * GIB,
            vram_reserved: 0,
        }
    }

    /// Standard architecture: every agent owns a weight copy + full context.
    pub fn standard_agent_bytes(&self) -> u64 {
        self.weight_bytes + self.kv_row_bytes * self.full_ctx as u64 + self.per_agent_overhead
    }

    /// Warp-Cortex side agent: landmarks + generation rows + overhead
    /// (weights shared via the Prism: zero marginal).
    pub fn warp_agent_bytes(&self) -> u64 {
        self.kv_row_bytes * (self.synapse_k + self.side_gen) as u64 + self.per_agent_overhead
    }

    /// Resident context bytes for a cache holding `fill_rows` rows under
    /// demand-paged allocation with `block_tokens`-row blocks (the KvPool):
    /// fill rounded up to whole blocks — what the tracker now measures,
    /// versus the eager full-capacity reservation of the seed design.
    #[allow(clippy::manual_div_ceil)] // spelled out to keep the MSRV permissive
    pub fn paged_context_bytes(&self, fill_rows: usize, block_tokens: usize) -> u64 {
        let bt = block_tokens.max(1);
        let blocks = (fill_rows + bt - 1) / bt;
        self.kv_row_bytes * (blocks * bt) as u64
    }

    /// Warp side agent under paged allocation: resident landmark+generation
    /// rows (block-rounded) + overhead.
    pub fn warp_agent_resident_bytes(&self, block_tokens: usize) -> u64 {
        self.paged_context_bytes(self.synapse_k + self.side_gen, block_tokens)
            + self.per_agent_overhead
    }

    /// Warp-Cortex side agent with its context in the warm int8 tier
    /// (parked / registered-prefix state quantized block-granularly).
    pub fn warp_agent_bytes_q8(&self) -> u64 {
        self.kv_row_bytes_q8 * (self.synapse_k + self.side_gen) as u64 + self.per_agent_overhead
    }

    /// Max agents under Warp-Cortex with the quantized tier enabled for
    /// side-agent context (the tiered-KV column of Table 1).
    pub fn max_agents_warp_q8(&self) -> u64 {
        let rest = self.budget().saturating_sub(self.weight_bytes + self.full_ctx_bytes());
        1 + rest / self.warp_agent_bytes_q8().max(1)
    }

    /// Total VRAM with `n` Warp-Cortex agents when side-agent context sits
    /// in the quantized tier.
    pub fn warp_total_bytes_q8(&self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.weight_bytes
            + self.full_ctx_bytes()
            + self.per_agent_overhead
            + (n - 1) * self.warp_agent_bytes_q8()
    }

    /// Synapse-only context bytes (the paper's "0.01 GB" row).
    pub fn synapse_bytes(&self) -> u64 {
        self.kv_row_bytes * self.synapse_k as u64
    }

    pub fn full_ctx_bytes(&self) -> u64 {
        self.kv_row_bytes * self.full_ctx as u64
    }

    fn budget(&self) -> u64 {
        self.vram_total - self.vram_reserved
    }

    /// Max agents under the standard architecture (first agent included).
    pub fn max_agents_standard(&self) -> u64 {
        self.budget() / self.standard_agent_bytes().max(1)
    }

    /// Max agents under Warp-Cortex (weights paid once).
    pub fn max_agents_warp(&self) -> u64 {
        let rest = self.budget().saturating_sub(self.weight_bytes + self.full_ctx_bytes());
        1 + rest / self.warp_agent_bytes().max(1)
    }

    /// Total VRAM with `n` Warp-Cortex agents (1 main + n-1 side).
    pub fn warp_total_bytes(&self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.weight_bytes
            + self.full_ctx_bytes()          // the main agent's own context
            + self.per_agent_overhead        // main agent overhead
            + (n - 1) * self.warp_agent_bytes()
    }

    /// Total VRAM with `n` standard agents.
    pub fn standard_total_bytes(&self, n: u64) -> u64 {
        n * self.standard_agent_bytes()
    }

    /// Compression ratio of the synapse vs full context (paper claims 98 %
    /// at L=32k, k=64 — ours: 1 - k/L).
    pub fn compression(&self) -> f64 {
        1.0 - self.synapse_k as f64 / self.full_ctx as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen_cfg() -> ModelConfig {
        ModelConfig {
            name: "qwen2_5_0_5b".into(),
            d_model: 896,
            n_layers: 24,
            n_heads: 14,
            n_kv_heads: 2,
            d_ff: 4864,
            vocab_size: 151936,
            head_dim: 64,
            rope_theta: 1e6,
            param_count: 494_032_768,
        }
    }

    #[test]
    fn tracker_conservation() {
        let t = MemoryTracker::new();
        let g1 = t.alloc(MemKind::MainKv, 1000);
        let g2 = t.alloc(MemKind::SideKv, 500);
        assert_eq!(t.total_live(), 1500);
        drop(g1);
        assert_eq!(t.total_live(), 500);
        drop(g2);
        assert_eq!(t.total_live(), 0);
        let s = t.snapshot();
        assert_eq!(s.allocs, 2);
        assert_eq!(s.frees, 2);
        assert_eq!(s.peak_per_kind[MemKind::MainKv as usize], 1000);
    }

    #[test]
    fn guard_resize() {
        let t = MemoryTracker::new();
        let mut g = t.alloc(MemKind::Synapse, 100);
        g.resize(250);
        assert_eq!(t.live_bytes(MemKind::Synapse), 250);
        drop(g);
        assert_eq!(t.live_bytes(MemKind::Synapse), 0);
    }

    #[test]
    fn table1_shape_holds() {
        // The paper's Table 1: standard ≈ 12 agents, warp ≫ standard.
        let m = MemoryModel::qwen05b_on_4090(&qwen_cfg());
        // weights ≈ 1.2 GB
        assert!(m.weight_bytes > 1_000_000_000 && m.weight_bytes < 1_400_000_000);
        // full 32k fp16 context ≈ 0.4 GB (paper: ~0.5 GB)
        assert!(m.full_ctx_bytes() > 350_000_000 && m.full_ctx_bytes() < 550_000_000);
        // synapse ≈ 0.8 MB ≤ paper's 0.01 GB row
        assert!(m.synapse_bytes() < 10 * MIB);
        let std_max = m.max_agents_standard();
        let warp_max = m.max_agents_warp();
        assert!((10..=16).contains(&std_max), "standard max {std_max}");
        assert!(warp_max > 400, "warp max {warp_max}");
        assert!(warp_max > 20 * std_max);
    }

    #[test]
    fn table2_shape_holds() {
        // Measured table: ~13 MB/agent marginal, 100 agents ≈ 1.3 GB delta.
        let m = MemoryModel::qwen05b_on_4090(&qwen_cfg());
        let base = m.warp_total_bytes(1);
        let at100 = m.warp_total_bytes(100);
        let delta = at100 - base;
        let per_agent = delta / 99;
        assert!(
            (10 * MIB..=16 * MIB).contains(&per_agent),
            "per-agent {} MB",
            per_agent / MIB
        );
        assert!(delta < 2 * GIB, "delta {}", fmt_bytes(delta as f64));
        // monotone linear scaling
        assert!(m.warp_total_bytes(50) > m.warp_total_bytes(10));
    }

    #[test]
    fn paged_resident_tracks_fill_not_capacity() {
        let m = MemoryModel::qwen05b_on_4090(&qwen_cfg());
        // 5 rows in 16-row blocks → 1 block resident
        assert_eq!(m.paged_context_bytes(5, 16), m.kv_row_bytes * 16);
        assert_eq!(m.paged_context_bytes(0, 16), 0);
        assert_eq!(m.paged_context_bytes(17, 16), m.kv_row_bytes * 32);
        // a short-context agent is far cheaper resident than its configured
        // full context — the point of demand paging
        assert!(m.paged_context_bytes(96, 16) * 100 < m.full_ctx_bytes());
        // and the paged side-agent figure never exceeds the eager one
        assert!(m.warp_agent_resident_bytes(16) <= m.warp_agent_bytes() + m.kv_row_bytes * 16);
    }

    #[test]
    fn quantized_tier_multiplies_capacity() {
        let m = MemoryModel::qwen05b_on_4090(&qwen_cfg());
        // an int8 row (values + per-layer scales) is about half the fp16 row
        assert!(m.kv_row_bytes_q8 < m.kv_row_bytes);
        assert!(m.kv_row_bytes_q8 * 2 < m.kv_row_bytes + m.kv_row_bytes / 4);
        // and capacity strictly improves even with overhead dominating
        assert!(m.max_agents_warp_q8() > m.max_agents_warp());
        assert!(m.warp_total_bytes_q8(100) < m.warp_total_bytes(100));
    }

    #[test]
    fn compression_claim() {
        let m = MemoryModel::qwen05b_on_4090(&qwen_cfg());
        assert!(m.compression() > 0.98);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert!(fmt_bytes(2_500_000.0).ends_with("MB"));
        assert!(fmt_bytes(3.2e9).ends_with("GB"));
    }
}
