//! The Prism (paper §3.2): Singleton Weight Sharing + the agent registry.
//!
//! Weights live in device buffers uploaded exactly once (see
//! `runtime::device`); every agent holds an `Arc<Engine>` — a pointer, not a
//! copy.  The Prism tracks the live agent population, hands each agent a
//! pool-backed cache from the shared [`KvPool`], and wires the cache to the
//! [`MemoryTracker`] so the Table-2 bench measures *resident-block* bytes:
//! the charge grows as the cache fills and shrinks as blocks are released —
//! not the configured capacity the seed used to reserve eagerly.  Under
//! prefix sharing each agent's charge covers only its *private* blocks;
//! registry-shared blocks (common prompt prefixes, landmark seeds) are
//! charged once globally under `MemKind::SharedKv` via
//! [`KvPool::track_shared`], so the shared-prefix term of the context bound
//! is O(1) in the agent count.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::memory::{MemKind, MemoryTracker};
use crate::model::{Engine, KvCache, KvPool};
use crate::util::sync::{LockRank, RankedMutex};

/// Kind of registered agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    Main,
    Side,
}

/// Unique agent identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentId(pub u64);

#[derive(Debug)]
struct AgentMeta {
    kind: AgentKind,
    registered: Instant,
    /// Bytes an eager full-capacity allocation would have reserved (the
    /// pre-pool figure, kept for capacity-vs-resident comparisons).
    capacity_bytes: u64,
}

/// A registered agent's handle: carries its pool-backed cache (which in
/// turn carries its memory charge).  Dropping the ticket releases the
/// registry entry, the cache's blocks, and the accounted bytes.
pub struct AgentTicket {
    pub id: AgentId,
    pub kind: AgentKind,
    pub kv: KvCache,
    prism: Arc<PrismInner>,
}

impl std::fmt::Debug for AgentTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AgentTicket")
            .field("id", &self.id)
            .field("kind", &self.kind)
            .field("kv_len", &self.kv.len())
            .finish()
    }
}

impl Drop for AgentTicket {
    fn drop(&mut self) {
        self.prism.agents.lock().remove(&self.id);
    }
}

#[derive(Debug)]
struct PrismInner {
    /// Ranked [`LockRank::PrismAgents`]: never held across a pool or
    /// scheduler lock — registration and the population gauges touch only
    /// this map.
    agents: RankedMutex<HashMap<AgentId, AgentMeta>>,
    next_id: AtomicU64,
}

/// Population counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Population {
    pub main: usize,
    pub side: usize,
}

impl Population {
    pub fn total(&self) -> usize {
        self.main + self.side
    }
}

/// The singleton model instance shared by all agents.
pub struct Prism {
    engine: Arc<Engine>,
    tracker: Arc<MemoryTracker>,
    pool: Arc<KvPool>,
    inner: Arc<PrismInner>,
    /// Keeps the weights' memory charge alive for the Prism's lifetime.
    _weights_mem: super::memory::MemGuard,
}

impl Prism {
    /// Wrap an engine; agents rent from the engine's default block pool.
    pub fn new(engine: Arc<Engine>, tracker: Arc<MemoryTracker>) -> Arc<Prism> {
        let pool = engine.pool().clone();
        Prism::with_pool(engine, tracker, pool)
    }

    /// Wrap an engine with an explicit pool (the orchestrator's, so its
    /// block-size/capacity/reclaim knobs govern every agent cache).
    /// Charges the (singleton) weight bytes once.
    pub fn with_pool(
        engine: Arc<Engine>,
        tracker: Arc<MemoryTracker>,
        pool: Arc<KvPool>,
    ) -> Arc<Prism> {
        let weight_bytes = engine.device().weight_bytes(&engine.config().name);
        let weights_mem = tracker.alloc(MemKind::Weights, weight_bytes);
        // One gauge for the pool's device-resident block copies: the pool
        // resizes it as buffers materialise on first write-through and free
        // on reclaim, so Table 2 shows both sides of each block (host rows
        // under Main/SideKv, the device copy under DeviceKv).
        pool.track_device(tracker.alloc(MemKind::DeviceKv, 0));
        // And one for registry-shared (prefix-cache) blocks: a block N
        // agents reference is charged here exactly once — the per-agent
        // Main/SideKv guards count only private blocks, so Table 2 never
        // multiply-counts a shared prompt prefix or landmark seed.
        pool.track_shared(tracker.alloc(MemKind::SharedKv, 0));
        // And one for the cold host slab: parked payloads leave their
        // device-tier charges (DeviceKv + Main/Side/SharedKv) and appear
        // here instead — host RAM, not VRAM — so every physical byte is
        // counted exactly once, in the tier it occupies.
        pool.track_host(tracker.alloc(MemKind::HostKv, 0));
        Arc::new(Prism {
            engine,
            tracker,
            pool,
            inner: Arc::new(PrismInner {
                agents: RankedMutex::new(LockRank::PrismAgents, HashMap::new()),
                next_id: AtomicU64::new(1),
            }),
            _weights_mem: weights_mem,
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.tracker
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Register a new agent: rents a pool-backed cache and attaches a live
    /// memory charge that tracks its resident blocks.
    pub fn register(&self, kind: AgentKind) -> Result<AgentTicket> {
        let (capacity, mem_kind) = match kind {
            AgentKind::Main => (self.engine.caps().main_ctx, MemKind::MainKv),
            AgentKind::Side => (self.engine.caps().side_ctx, MemKind::SideKv),
        };
        let mut kv = self.pool.new_cache(capacity);
        // Starts at 0 resident bytes; the cache resizes the guard on every
        // block rent/release.
        let guard = self.tracker.alloc(mem_kind, kv.bytes());
        kv.track(guard);
        let id = AgentId(self.inner.next_id.fetch_add(1, Ordering::Relaxed));
        self.inner.agents.lock().insert(
            id,
            AgentMeta {
                kind,
                registered: Instant::now(),
                capacity_bytes: kv.capacity_bytes(),
            },
        );
        Ok(AgentTicket {
            id,
            kind,
            kv,
            prism: self.inner.clone(),
        })
    }

    pub fn population(&self) -> Population {
        let agents = self.inner.agents.lock();
        let mut p = Population::default();
        for meta in agents.values() {
            match meta.kind {
                AgentKind::Main => p.main += 1,
                AgentKind::Side => p.side += 1,
            }
        }
        p
    }

    /// Total KV bytes the registered population would reserve under eager
    /// full-capacity allocation (contrast with the pool's resident bytes).
    pub fn registered_kv_bytes(&self) -> u64 {
        self.inner
            .agents
            .lock()
            .values()
            .map(|m| m.capacity_bytes)
            .sum()
    }

    /// Age of the oldest live agent (for eviction policies).
    pub fn oldest_agent_age(&self) -> Option<std::time::Duration> {
        self.inner
            .agents
            .lock()
            .values()
            .map(|m| m.registered.elapsed())
            .max()
    }
}

// Unit tests for the registry bookkeeping use the real engine and live in
// rust/tests/integration_cortex.rs (Prism requires a device).
