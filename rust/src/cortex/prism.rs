//! The Prism (paper §3.2): Singleton Weight Sharing + the agent registry.
//!
//! Weights live in device buffers uploaded exactly once (see
//! `runtime::device`); every agent holds an `Arc<Engine>` — a pointer, not a
//! copy.  The Prism tracks the live agent population and charges each
//! agent's KV bytes to the [`MemoryTracker`], which is what the Table-2
//! bench measures.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use super::memory::{MemGuard, MemKind, MemoryTracker};
use crate::model::{Engine, KvCache};

/// Kind of registered agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentKind {
    Main,
    Side,
}

/// Unique agent identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AgentId(pub u64);

#[derive(Debug)]
struct AgentMeta {
    kind: AgentKind,
    registered: Instant,
    kv_bytes: u64,
}

/// A registered agent's handle: carries its cache and its memory charge.
/// Dropping the ticket releases both registry entry and accounted bytes.
pub struct AgentTicket {
    pub id: AgentId,
    pub kind: AgentKind,
    pub kv: KvCache,
    _mem: MemGuard,
    prism: Arc<PrismInner>,
}

impl std::fmt::Debug for AgentTicket {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("AgentTicket")
            .field("id", &self.id)
            .field("kind", &self.kind)
            .field("kv_len", &self.kv.len())
            .finish()
    }
}

impl Drop for AgentTicket {
    fn drop(&mut self) {
        self.prism.agents.lock().unwrap().remove(&self.id);
    }
}

#[derive(Debug)]
struct PrismInner {
    agents: Mutex<HashMap<AgentId, AgentMeta>>,
    next_id: AtomicU64,
}

/// Population counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Population {
    pub main: usize,
    pub side: usize,
}

impl Population {
    pub fn total(&self) -> usize {
        self.main + self.side
    }
}

/// The singleton model instance shared by all agents.
pub struct Prism {
    engine: Arc<Engine>,
    tracker: Arc<MemoryTracker>,
    inner: Arc<PrismInner>,
    /// Keeps the weights' memory charge alive for the Prism's lifetime.
    _weights_mem: MemGuard,
}

impl Prism {
    /// Wrap an engine; charges the (singleton) weight bytes once.
    pub fn new(engine: Arc<Engine>, tracker: Arc<MemoryTracker>) -> Arc<Prism> {
        let weight_bytes = engine.device().weight_bytes(&engine.config().name);
        let weights_mem = tracker.alloc(MemKind::Weights, weight_bytes);
        Arc::new(Prism {
            engine,
            tracker,
            inner: Arc::new(PrismInner {
                agents: Mutex::new(HashMap::new()),
                next_id: AtomicU64::new(1),
            }),
            _weights_mem: weights_mem,
        })
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    pub fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.tracker
    }

    /// Register a new agent: allocates its cache and charges its bytes.
    pub fn register(&self, kind: AgentKind) -> Result<AgentTicket> {
        let kv = match kind {
            AgentKind::Main => self.engine.new_main_cache(),
            AgentKind::Side => self.engine.new_side_cache(),
        };
        let mem_kind = match kind {
            AgentKind::Main => MemKind::MainKv,
            AgentKind::Side => MemKind::SideKv,
        };
        let bytes = kv.bytes();
        let guard = self.tracker.alloc(mem_kind, bytes);
        let id = AgentId(self.inner.next_id.fetch_add(1, Ordering::Relaxed));
        self.inner.agents.lock().unwrap().insert(
            id,
            AgentMeta {
                kind,
                registered: Instant::now(),
                kv_bytes: bytes,
            },
        );
        Ok(AgentTicket {
            id,
            kind,
            kv,
            _mem: guard,
            prism: self.inner.clone(),
        })
    }

    pub fn population(&self) -> Population {
        let agents = self.inner.agents.lock().unwrap();
        let mut p = Population::default();
        for meta in agents.values() {
            match meta.kind {
                AgentKind::Main => p.main += 1,
                AgentKind::Side => p.side += 1,
            }
        }
        p
    }

    /// Total KV bytes currently registered (cross-check for the tracker).
    pub fn registered_kv_bytes(&self) -> u64 {
        self.inner
            .agents
            .lock()
            .unwrap()
            .values()
            .map(|m| m.kv_bytes)
            .sum()
    }

    /// Age of the oldest live agent (for eviction policies).
    pub fn oldest_agent_age(&self) -> Option<std::time::Duration> {
        self.inner
            .agents
            .lock()
            .unwrap()
            .values()
            .map(|m| m.registered.elapsed())
            .max()
    }
}

// Unit tests for the registry bookkeeping use the real engine and live in
// rust/tests/integration_cortex.rs (Prism requires a device).
