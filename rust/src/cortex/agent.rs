//! Agent state machines: the Main Agent (the River) and side agents (the
//! Streams).
//!
//! A side agent's lifecycle (paper Fig. 1):
//!   1. seed its cache from the Topological Synapse (k landmark rows),
//!   2. absorb its task prompt (teacher-forced decode at continuation
//!      positions after the compressed context),
//!   3. generate a short thought until a stop byte or its budget,
//!   4. hand the thought + its final hidden state to the Validation Gate.
//!
//! Since the step-scheduler refactor a side agent is a **pollable token
//! source** ([`SideAgent`]): instead of a worker thread that blocks on a
//! per-token decode RPC, the agent exposes `next_request` (the token it
//! wants decoded next) and `feed` (consume the step result, append the KV
//! row, advance).  The [`crate::cortex::StepScheduler`] polls every
//! runnable agent each tick and fuses their items into one device op.  The
//! thread-blocking [`run_side_agent`] entry point remains for the legacy
//! [`crate::cortex::StreamScheduler`] worker-pool path.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::Batcher;
use super::prism::{AgentKind, AgentTicket, Prism};
use super::router::AgentRole;
use super::synapse::{SeedMode, Synapse};
use crate::model::{Engine, KvCache, PagedKv, RawDecode};
use crate::text::{Sampler, SamplerConfig, Tokenizer, EOS_ID};

/// A routed unit of side-agent work.
#[derive(Debug, Clone)]
pub struct SideTask {
    pub id: u64,
    /// The serving session that spawned this task
    /// ([`crate::cortex::SessionPermit::id`]); the step scheduler routes
    /// the outcome back to that session's queue only.  0 = legacy
    /// sessionless submission — the outcome goes to the global results
    /// channel (`poll_results`).
    pub session: u64,
    pub role: AgentRole,
    pub payload: String,
    /// Main-agent text position when the trigger fired (for gating context).
    pub main_pos: i32,
    pub spawned_at: Instant,
}

/// Terminal state of a side agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SideState {
    Finished,
    BudgetExhausted,
    Failed,
}

/// What a side agent returns to the coordinator.
#[derive(Debug)]
pub struct SideOutcome {
    pub task: SideTask,
    pub state: SideState,
    /// The generated thought (visible bytes only).
    pub text: String,
    pub tokens: Vec<i32>,
    /// Final-layer hidden state of the last generated token (gate input).
    pub hidden: Vec<f32>,
    /// Decode steps consumed (prompt + generation).
    pub steps: usize,
    /// Synapse version the agent was seeded from.
    pub synapse_version: u64,
    pub elapsed: Duration,
    pub error: Option<String>,
}

/// Shared context handed to every side agent.
pub struct SideContext {
    pub engine: Arc<Engine>,
    pub synapse: Arc<Synapse>,
    pub batcher: Arc<Batcher>,
    /// Registry + memory accounting (agents exist only while running).
    pub prism: Arc<Prism>,
    /// How side caches are seeded (Full / Coarse / Adaptive — §6.2).
    pub seed_mode: super::synapse::SeedMode,
    /// Max generated thought tokens.
    pub gen_budget: usize,
    pub sampler: SamplerConfig,
}

/// Run one side agent to completion (called on a Stream worker thread).
pub fn run_side_agent(ctx: &SideContext, task: SideTask) -> SideOutcome {
    let started = Instant::now();
    match run_side_inner(ctx, &task) {
        Ok((state, text, tokens, hidden, steps, version)) => SideOutcome {
            task,
            state,
            text,
            tokens,
            hidden,
            steps,
            synapse_version: version,
            elapsed: started.elapsed(),
            error: None,
        },
        Err(e) => SideOutcome {
            task,
            state: SideState::Failed,
            text: String::new(),
            tokens: vec![],
            hidden: vec![],
            steps: 0,
            synapse_version: 0,
            elapsed: started.elapsed(),
            error: Some(format!("{e:#}")),
        },
    }
}

type SideRun = (SideState, String, Vec<i32>, Vec<f32>, usize, u64);

fn run_side_inner(ctx: &SideContext, task: &SideTask) -> Result<SideRun> {
    let tk = Tokenizer::new();

    // 1. Register with the Prism (just-in-time existence: the ticket's drop
    //    at function exit returns the agent's blocks to the shared pool)
    //    and seed its rented cache in place from the synapse landmarks
    //    (witness reconstruction).
    let mut ticket = ctx.prism.register(AgentKind::Side)?;
    let (mut pos, version) = ctx.synapse.seed_into(&mut ticket.kv, ctx.seed_mode)?;
    let kv = &mut ticket.kv;

    // 2. Absorb the task prompt at continuation positions.  The prompt
    //    mirrors the corpus' stream sections so the trained byte-LM stays
    //    in-distribution.
    let prompt = format!("\nstream: [THOUGHT] {}: ", task.payload);
    let prompt_ids = tk.encode(&prompt, false);
    let mut steps = 0usize;
    let mut last = None;
    // keep room for generation
    let absorb = prompt_ids
        .len()
        .min(kv.remaining().saturating_sub(ctx.gen_budget.min(8)));
    for &id in &prompt_ids[..absorb] {
        last = Some(ctx.batcher.decode(id, pos, kv)?);
        pos += 1;
        steps += 1;
    }

    // 3. Generate the thought.
    let mut sampler = Sampler::new(SamplerConfig {
        seed: ctx.sampler.seed ^ task.id,
        ..ctx.sampler.clone()
    });
    let mut text = String::new();
    let mut tokens = Vec::new();
    let mut state = SideState::BudgetExhausted;
    let mut hidden = last.as_ref().map(|o| o.hidden.clone()).unwrap_or_default();
    for _ in 0..ctx.gen_budget {
        if kv.remaining() == 0 {
            break;
        }
        let logits = match &last {
            Some(out) => &out.logits,
            None => break,
        };
        let id = sampler.sample(logits);
        if id == EOS_ID {
            state = SideState::Finished;
            break;
        }
        if let Some(b) = tk.decode_one(id) {
            if b == b'\n' || b == b']' {
                state = SideState::Finished;
                break;
            }
            text.push(b as char);
        }
        tokens.push(id);
        let out = ctx.batcher.decode(id, pos, kv)?;
        hidden = out.hidden.clone();
        last = Some(out);
        pos += 1;
        steps += 1;
    }

    Ok((state, text, tokens, hidden, steps, version))
}

// ── Pollable side agents (the step-scheduler path) ──────────────────────

/// What a pollable side agent decodes into: a prism-registered ticket in
/// production (its drop returns the blocks and the population slot), or a
/// bare pool cache in the executor-seam tests and benches that run without
/// an engine.
pub enum AgentCache {
    Ticket(AgentTicket),
    Bare(KvCache),
}

impl AgentCache {
    pub fn kv(&mut self) -> &mut KvCache {
        match self {
            AgentCache::Ticket(t) => &mut t.kv,
            AgentCache::Bare(kv) => kv,
        }
    }

    pub fn kv_ref(&self) -> &KvCache {
        match self {
            AgentCache::Ticket(t) => &t.kv,
            AgentCache::Bare(kv) => kv,
        }
    }
}

/// Everything [`SideAgent::spawn`] needs to register and seed a fresh side
/// agent (the step scheduler's production spawner captures one of these).
pub struct StepAgentCtx {
    pub prism: Arc<Prism>,
    pub synapse: Arc<Synapse>,
    pub seed_mode: SeedMode,
    pub gen_budget: usize,
    pub sampler: SamplerConfig,
}

/// A side agent as a pollable state machine.  Semantics mirror
/// [`run_side_agent`] step for step — absorb the task prompt at
/// continuation positions, then sample a short thought until a stop byte,
/// EOS or the budget — but decoding is inverted: the scheduler asks for
/// the next `(token, pos)` item, runs it (fused with every other runnable
/// agent), and feeds the raw result back.
pub struct SideAgent {
    task: SideTask,
    /// `None` only for born-failed agents (spawn error): they are `done`
    /// from birth, so no decode path ever dereferences the cache.
    cache: Option<AgentCache>,
    tokenizer: Tokenizer,
    sampler: Sampler,
    prompt_ids: Vec<i32>,
    /// Prompt tokens to teacher-force (prompt length capped to leave
    /// generation room).
    absorb: usize,
    absorb_idx: usize,
    gen_budget: usize,
    generated: usize,
    pos: i32,
    steps: usize,
    state: SideState,
    text: String,
    tokens: Vec<i32>,
    hidden: Vec<f32>,
    last_logits: Option<Vec<f32>>,
    /// The item handed out by `next_request` and not yet fed back, so a
    /// repeated poll cannot re-sample.
    inflight: Option<(i32, i32)>,
    version: u64,
    started: Instant,
    error: Option<String>,
    done: bool,
}

impl SideAgent {
    /// Register with the Prism and seed from the synapse.  Never fails:
    /// a registration/seeding error yields a born-finished agent whose
    /// outcome is `Failed` (the scheduler delivers it like any other).
    pub fn spawn(ctx: &StepAgentCtx, task: SideTask) -> SideAgent {
        let started = Instant::now();
        let spawned = (|| -> Result<(AgentTicket, i32, u64)> {
            let mut ticket = ctx.prism.register(AgentKind::Side)?;
            let (pos, version) = ctx.synapse.seed_into(&mut ticket.kv, ctx.seed_mode)?;
            Ok((ticket, pos, version))
        })();
        match spawned {
            Ok((ticket, pos, version)) => {
                let tk = Tokenizer::new();
                let prompt = format!("\nstream: [THOUGHT] {}: ", task.payload);
                let prompt_ids = tk.encode(&prompt, false);
                let sampler_cfg = SamplerConfig {
                    seed: ctx.sampler.seed ^ task.id,
                    ..ctx.sampler.clone()
                };
                SideAgent::assemble(
                    task,
                    AgentCache::Ticket(ticket),
                    tk,
                    pos,
                    version,
                    prompt_ids,
                    ctx.gen_budget,
                    sampler_cfg,
                    started,
                )
            }
            Err(e) => SideAgent::born_failed(task, format!("{e:#}"), started),
        }
    }

    /// Executor-seam constructor: an already-seeded cache, explicit prompt
    /// ids and sampling — no prism, synapse or engine required.  Drives the
    /// scheduler's fused-vs-sequential equivalence proptest and the
    /// continuous-batching bench host-only.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        task: SideTask,
        cache: AgentCache,
        pos: i32,
        version: u64,
        prompt_ids: Vec<i32>,
        gen_budget: usize,
        sampler: SamplerConfig,
    ) -> SideAgent {
        let sampler_cfg = SamplerConfig {
            seed: sampler.seed ^ task.id,
            ..sampler
        };
        SideAgent::assemble(
            task,
            cache,
            Tokenizer::new(),
            pos,
            version,
            prompt_ids,
            gen_budget,
            sampler_cfg,
            Instant::now(),
        )
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        task: SideTask,
        mut cache: AgentCache,
        tokenizer: Tokenizer,
        pos: i32,
        version: u64,
        prompt_ids: Vec<i32>,
        gen_budget: usize,
        sampler_cfg: SamplerConfig,
        started: Instant,
    ) -> SideAgent {
        // Same absorb cap as the blocking path: keep room for generation.
        let absorb = prompt_ids
            .len()
            .min(cache.kv().remaining().saturating_sub(gen_budget.min(8)));
        SideAgent {
            task,
            cache: Some(cache),
            tokenizer,
            sampler: Sampler::new(sampler_cfg),
            prompt_ids,
            absorb,
            absorb_idx: 0,
            gen_budget,
            generated: 0,
            pos,
            steps: 0,
            state: SideState::BudgetExhausted,
            text: String::new(),
            tokens: Vec::new(),
            hidden: Vec::new(),
            last_logits: None,
            inflight: None,
            version,
            started,
            error: None,
            done: false,
        }
    }

    fn born_failed(task: SideTask, error: String, started: Instant) -> SideAgent {
        SideAgent {
            task,
            cache: None,
            tokenizer: Tokenizer::new(),
            sampler: Sampler::new(SamplerConfig::greedy()),
            prompt_ids: Vec::new(),
            absorb: 0,
            absorb_idx: 0,
            gen_budget: 0,
            generated: 0,
            pos: 0,
            steps: 0,
            state: SideState::Failed,
            text: String::new(),
            tokens: Vec::new(),
            hidden: Vec::new(),
            last_logits: None,
            inflight: None,
            version: 0,
            started,
            error: Some(error),
            done: true,
        }
    }

    pub fn task_id(&self) -> u64 {
        self.task.id
    }

    pub fn is_done(&self) -> bool {
        self.done
    }

    fn kv(&mut self) -> &mut KvCache {
        self.cache
            .as_mut()
            .expect("live side agent has a cache")
            .kv()
    }

    /// Paged view of the agent's cache for the next fused op.
    pub fn paged(&self) -> PagedKv {
        self.cache
            .as_ref()
            .expect("live side agent has a cache")
            .kv_ref()
            .paged()
    }

    /// The next `(token, position)` this agent wants decoded, or `None`
    /// once it has finished.  Idempotent until the matching [`Self::feed`]:
    /// repeated polls return the same item without re-sampling.
    pub fn next_request(&mut self) -> Option<(i32, i32)> {
        if self.done {
            return None;
        }
        if let Some(req) = self.inflight {
            return Some(req);
        }
        // Phase 1: absorb the task prompt (teacher forcing).
        if self.absorb_idx < self.absorb {
            let req = (self.prompt_ids[self.absorb_idx], self.pos);
            self.inflight = Some(req);
            return Some(req);
        }
        // Phase 2: generate the thought.
        if self.generated >= self.gen_budget || self.kv().remaining() == 0 {
            self.done = true; // state stays BudgetExhausted
            return None;
        }
        let id = match &self.last_logits {
            Some(logits) => self.sampler.sample(logits),
            None => {
                // no absorb step ran and nothing was seeded to sample from
                self.done = true;
                return None;
            }
        };
        if id == EOS_ID {
            self.state = SideState::Finished;
            self.done = true;
            return None;
        }
        if let Some(b) = self.tokenizer.decode_one(id) {
            if b == b'\n' || b == b']' {
                self.state = SideState::Finished;
                self.done = true;
                return None;
            }
            self.text.push(b as char);
        }
        self.tokens.push(id);
        self.generated += 1;
        let req = (id, self.pos);
        self.inflight = Some(req);
        Some(req)
    }

    /// Consume one step result: append the KV row, advance the phase.  An
    /// append failure marks the agent `Failed` (surfaced in its outcome).
    pub fn feed(&mut self, step: RawDecode) {
        self.inflight = None;
        if let Err(e) = self.kv().append_row(&step.k_new, &step.v_new) {
            self.fail(format!("append: {e:#}"));
            return;
        }
        self.hidden = step.hidden;
        self.last_logits = Some(step.logits);
        if self.absorb_idx < self.absorb {
            self.absorb_idx += 1;
        }
        self.pos += 1;
        self.steps += 1;
    }

    /// Mark the agent failed (device error, scheduler shutdown, ...).
    pub fn fail(&mut self, error: String) {
        self.inflight = None;
        self.state = SideState::Failed;
        self.error = Some(error);
        self.done = true;
    }

    /// Terminal outcome; consumes the agent (its ticket's drop returns the
    /// cache blocks to the pool).
    pub fn into_outcome(self) -> SideOutcome {
        SideOutcome {
            state: self.state,
            text: self.text,
            tokens: self.tokens,
            hidden: self.hidden,
            steps: self.steps,
            synapse_version: self.version,
            elapsed: self.started.elapsed(),
            error: self.error,
            task: self.task,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn side_task_fields() {
        let t = SideTask {
            id: 7,
            session: 0,
            role: AgentRole::Verify,
            payload: "check the date".into(),
            main_pos: 42,
            spawned_at: Instant::now(),
        };
        assert_eq!(t.role.name(), "verify");
        assert_eq!(t.payload, "check the date");
    }
}
