//! Agent state machines: the Main Agent (the River) and side agents (the
//! Streams).
//!
//! A side agent's lifecycle (paper Fig. 1):
//!   1. seed its cache from the Topological Synapse (k landmark rows),
//!   2. absorb its task prompt (teacher-forced decode at continuation
//!      positions after the compressed context),
//!   3. generate a short thought until a stop byte or its budget,
//!   4. hand the thought + its final hidden state to the Validation Gate.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::batcher::Batcher;
use super::prism::{AgentKind, Prism};
use super::router::AgentRole;
use super::synapse::Synapse;
use crate::model::Engine;
use crate::text::{Sampler, SamplerConfig, Tokenizer, EOS_ID};

/// A routed unit of side-agent work.
#[derive(Debug, Clone)]
pub struct SideTask {
    pub id: u64,
    pub role: AgentRole,
    pub payload: String,
    /// Main-agent text position when the trigger fired (for gating context).
    pub main_pos: i32,
    pub spawned_at: Instant,
}

/// Terminal state of a side agent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SideState {
    Finished,
    BudgetExhausted,
    Failed,
}

/// What a side agent returns to the coordinator.
#[derive(Debug)]
pub struct SideOutcome {
    pub task: SideTask,
    pub state: SideState,
    /// The generated thought (visible bytes only).
    pub text: String,
    pub tokens: Vec<i32>,
    /// Final-layer hidden state of the last generated token (gate input).
    pub hidden: Vec<f32>,
    /// Decode steps consumed (prompt + generation).
    pub steps: usize,
    /// Synapse version the agent was seeded from.
    pub synapse_version: u64,
    pub elapsed: Duration,
    pub error: Option<String>,
}

/// Shared context handed to every side agent.
pub struct SideContext {
    pub engine: Arc<Engine>,
    pub synapse: Arc<Synapse>,
    pub batcher: Arc<Batcher>,
    /// Registry + memory accounting (agents exist only while running).
    pub prism: Arc<Prism>,
    /// How side caches are seeded (Full / Coarse / Adaptive — §6.2).
    pub seed_mode: super::synapse::SeedMode,
    /// Max generated thought tokens.
    pub gen_budget: usize,
    pub sampler: SamplerConfig,
}

/// Run one side agent to completion (called on a Stream worker thread).
pub fn run_side_agent(ctx: &SideContext, task: SideTask) -> SideOutcome {
    let started = Instant::now();
    match run_side_inner(ctx, &task) {
        Ok((state, text, tokens, hidden, steps, version)) => SideOutcome {
            task,
            state,
            text,
            tokens,
            hidden,
            steps,
            synapse_version: version,
            elapsed: started.elapsed(),
            error: None,
        },
        Err(e) => SideOutcome {
            task,
            state: SideState::Failed,
            text: String::new(),
            tokens: vec![],
            hidden: vec![],
            steps: 0,
            synapse_version: 0,
            elapsed: started.elapsed(),
            error: Some(format!("{e:#}")),
        },
    }
}

type SideRun = (SideState, String, Vec<i32>, Vec<f32>, usize, u64);

fn run_side_inner(ctx: &SideContext, task: &SideTask) -> Result<SideRun> {
    let tk = Tokenizer::new();

    // 1. Register with the Prism (just-in-time existence: the ticket's drop
    //    at function exit returns the agent's blocks to the shared pool)
    //    and seed its rented cache in place from the synapse landmarks
    //    (witness reconstruction).
    let mut ticket = ctx.prism.register(AgentKind::Side)?;
    let (mut pos, version) = ctx.synapse.seed_into(&mut ticket.kv, ctx.seed_mode)?;
    let kv = &mut ticket.kv;

    // 2. Absorb the task prompt at continuation positions.  The prompt
    //    mirrors the corpus' stream sections so the trained byte-LM stays
    //    in-distribution.
    let prompt = format!("\nstream: [THOUGHT] {}: ", task.payload);
    let prompt_ids = tk.encode(&prompt, false);
    let mut steps = 0usize;
    let mut last = None;
    // keep room for generation
    let absorb = prompt_ids
        .len()
        .min(kv.remaining().saturating_sub(ctx.gen_budget.min(8)));
    for &id in &prompt_ids[..absorb] {
        last = Some(ctx.batcher.decode(id, pos, kv)?);
        pos += 1;
        steps += 1;
    }

    // 3. Generate the thought.
    let mut sampler = Sampler::new(SamplerConfig {
        seed: ctx.sampler.seed ^ task.id,
        ..ctx.sampler.clone()
    });
    let mut text = String::new();
    let mut tokens = Vec::new();
    let mut state = SideState::BudgetExhausted;
    let mut hidden = last.as_ref().map(|o| o.hidden.clone()).unwrap_or_default();
    for _ in 0..ctx.gen_budget {
        if kv.remaining() == 0 {
            break;
        }
        let logits = match &last {
            Some(out) => &out.logits,
            None => break,
        };
        let id = sampler.sample(logits);
        if id == EOS_ID {
            state = SideState::Finished;
            break;
        }
        if let Some(b) = tk.decode_one(id) {
            if b == b'\n' || b == b']' {
                state = SideState::Finished;
                break;
            }
            text.push(b as char);
        }
        tokens.push(id);
        let out = ctx.batcher.decode(id, pos, kv)?;
        hidden = out.hidden.clone();
        last = Some(out);
        pos += 1;
        steps += 1;
    }

    Ok((state, text, tokens, hidden, steps, version))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn side_task_fields() {
        let t = SideTask {
            id: 7,
            role: AgentRole::Verify,
            payload: "check the date".into(),
            main_pos: 42,
            spawned_at: Instant::now(),
        };
        assert_eq!(t.role.name(), "verify");
        assert_eq!(t.payload, "check the date");
    }
}
