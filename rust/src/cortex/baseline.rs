//! The "Standard Architecture" baseline (the comparison column of Tables 1
//! and 2): every agent owns a full weight copy and a full-length context.
//!
//! On this substrate we *allocate* the per-agent full-context KV cache for
//! real (host buffers, tracked byte-exactly) and *account* the per-agent
//! weight copy analytically — actually duplicating weight buffers per agent
//! would only re-measure `memcpy`, and the paper's point is the arithmetic.
//! DESIGN.md §4 records this substitution.

use std::sync::Arc;

use anyhow::Result;

use super::memory::{MemGuard, MemKind, MemoryTracker};
use crate::model::{Engine, KvCache};
use crate::runtime::Lane;

/// One standard-architecture agent: private "weights" + full context.
pub struct BaselineAgent {
    pub kv: KvCache,
    _kv_mem: MemGuard,
    _weight_mem: MemGuard,
}

/// A population of standard-architecture agents.
pub struct StandardArchitecture {
    engine: Arc<Engine>,
    tracker: Arc<MemoryTracker>,
    agents: Vec<BaselineAgent>,
}

impl StandardArchitecture {
    pub fn new(engine: Arc<Engine>, tracker: Arc<MemoryTracker>) -> StandardArchitecture {
        StandardArchitecture {
            engine,
            tracker,
            agents: Vec::new(),
        }
    }

    /// Spawn one agent: full-context KV charged at its eager full-capacity
    /// reservation (the standard architecture pre-allocates; the pool-backed
    /// resident figure would understate the baseline), weight copy
    /// accounted.
    pub fn spawn(&mut self) -> Result<usize> {
        let kv = self.engine.new_main_cache();
        let kv_mem = self.tracker.alloc(MemKind::MainKv, kv.capacity_bytes());
        let weight_bytes = self.engine.device().weight_bytes(&self.engine.config().name);
        let weight_mem = self.tracker.alloc(MemKind::Weights, weight_bytes);
        self.agents.push(BaselineAgent {
            kv,
            _kv_mem: kv_mem,
            _weight_mem: weight_mem,
        });
        Ok(self.agents.len() - 1)
    }

    pub fn len(&self) -> usize {
        self.agents.len()
    }

    pub fn is_empty(&self) -> bool {
        self.agents.is_empty()
    }

    /// Run a prompt through agent `idx` (functionally identical to the
    /// shared-weight path — the baseline differs in memory, not math).
    pub fn prefill(&mut self, idx: usize, tokens: &[i32]) -> Result<Vec<f32>> {
        let agent = &mut self.agents[idx];
        let out = self.engine.prefill(tokens, &mut agent.kv, Lane::Stream)?;
        Ok(out.hidden_last)
    }

    pub fn total_tracked_bytes(&self) -> i64 {
        self.tracker.total_live()
    }
}

#[cfg(test)]
mod tests {
    // Allocation bookkeeping with a real engine is covered in
    // rust/tests/integration_cortex.rs and the table2 bench.
}
