//! The Warp-Cortex orchestrator: composes the Prism, Synapse, Router, Gate,
//! Injector and the River & Stream scheduler into the full system of the
//! paper's Figure 1.
//!
//! `run_episode` is the canonical serving loop:
//!
//! ```text
//! prefill (River) ─► decode loop ──► token stream ─► Router
//!        │              ▲   │                          │ trigger
//!        ▼              │   ▼ main step                ▼
//!   Synapse push    inject  STEP SCHEDULER ◄─── side agents (pollable
//!   (Background)            one fused device op       token sources)
//!                           per tick: main + sides
//! ```
//!
//! Decode scheduling is iteration-level (continuous batching): every
//! decode step — the main agent's and every side agent's — flows through
//! the [`StepScheduler`], which fuses all runnable agents' next tokens
//! into one `decode_batch` device op per tick.  The main step rides lane 0
//! at River priority while its context fits a side lane, and runs as its
//! own River op ahead of the side batch afterwards, preserving the
//! River/Stream lane contract without serializing the op stream.
//!
//! Context memory is device-resident end to end: every cache write (prefill
//! load, decode append, synapse seed, injection) goes through to the shared
//! pool's device block copies, and every decode step — main-agent River
//! steps and batched side steps alike — ships only a block table.  The
//! episode report's [`PoolStats`] carries the measured `h2d_bytes` /
//! `dev_gathers` gauges, and the prism charges the device copies to
//! `MemKind::DeviceKv`.
//!
//! Identical prompt prefixes are shared copy-on-write through the pool's
//! content-addressed registry: [`WarpCortex::start_main`] goes through
//! `Engine::prefill_shared`, so the first agent of a prompt runs the one
//! cold prefill and every later agent adopts the registered blocks by
//! reference, decoding only the uncovered tail (zero prefill executions,
//! O(1) fresh blocks).  Synapse seeds dedup the same way in `seed_into`.
//! The registry's hit/miss/evict/CoW gauges ride on [`PoolStats`] and the
//! `/stats` endpoint; shared blocks are charged once under
//! `MemKind::SharedKv`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::agent::{SideAgent, SideOutcome, SideTask, StepAgentCtx};
use super::gate::{Gate, GateStats};
use super::inject::{InjectStats, Injector};
use super::memory::{MemSnapshot, MemoryTracker};
use super::prism::{AgentKind, AgentTicket, Prism};
use super::router::{Router, RouterConfig, Trigger};
use super::step::{AdmitGate, AgentSpawner, FusedExec, StepConfig, StepScheduler, StepStats};
use super::synapse::{Synapse, SynapseStats};
use crate::metrics::{Histogram, Throughput};
use crate::model::{Engine, KvPool, KvPoolConfig, PoolStats};
use crate::runtime::Lane;
use crate::text::{Sampler, SamplerConfig, Tokenizer, EOS_ID};

/// Orchestrator configuration.
#[derive(Debug, Clone)]
pub struct CortexConfig {
    /// Model config name (must be loaded on the device).
    pub model: String,
    /// Max concurrently *decoding* side agents (step-scheduler active set).
    pub max_side_agents: usize,
    /// Additional parked tasks beyond the active ones (admission queue).
    pub max_queued_tasks: usize,
    /// Refresh the synapse every this many main-agent tokens.
    pub synapse_refresh_every: usize,
    /// Side-agent thought budget (generated tokens).
    pub side_gen_budget: usize,
    /// Enable Referential Injection of gate-accepted thoughts.
    pub inject_enabled: bool,
    /// Rows always reserved for main-agent generation (injection headroom).
    pub inject_reserve_rows: usize,
    /// Validation-gate threshold θ (None = artifact default, 0.5).
    pub gate_theta: Option<f32>,
    /// Main-agent sampling.
    pub sampler: SamplerConfig,
    /// Side-agent sampling.
    pub side_sampler: SamplerConfig,
    /// Legacy linger window of the [`super::Batcher`] API.  The serving
    /// path batches at iteration level through the step scheduler and
    /// never lingers; kept for callers assembling the legacy batcher
    /// directly.
    pub batch_linger: Duration,
    /// Ride the main step on lane 0 of the fused batch op while its
    /// context fits a side-capacity lane (one device op per tick).  Off =
    /// the main step always runs as its own River op ahead of the side
    /// batch (two ops per mixed tick, strictest lane isolation).
    pub fuse_main: bool,
    pub router: RouterConfig,
    /// Side-cache seeding (Full, or the §6.2 Coarse/Adaptive extensions).
    pub seed_mode: crate::cortex::synapse::SeedMode,
    /// Shared KV block pool knobs.  The orchestrator adopts the engine's
    /// pool (one pool per engine) and applies the runtime limits here:
    /// capacity ceiling (`max_blocks`, 0 = unbounded) and reclaim policy
    /// (`retain_free_blocks`).  `block_tokens` must match the engine pool's
    /// paging granularity (fixed at engine construction via
    /// `Engine::new_with_pool`); a mismatch is rejected at assembly.
    pub kv_pool: KvPoolConfig,
}

impl Default for CortexConfig {
    fn default() -> Self {
        CortexConfig {
            model: "small".into(),
            max_side_agents: 4,
            max_queued_tasks: 16,
            synapse_refresh_every: 32,
            side_gen_budget: 24,
            inject_enabled: true,
            inject_reserve_rows: 64,
            gate_theta: None,
            sampler: SamplerConfig::default(),
            side_sampler: SamplerConfig {
                temperature: 0.7,
                ..SamplerConfig::default()
            },
            batch_linger: Duration::from_micros(500),
            fuse_main: true,
            router: RouterConfig::default(),
            seed_mode: crate::cortex::synapse::SeedMode::Full,
            kv_pool: KvPoolConfig::default(),
        }
    }
}

/// One recorded coordination event (for reports and the council example).
#[derive(Debug, Clone)]
pub enum Event {
    Spawned {
        task_id: u64,
        tag: String,
        payload: String,
        at_token: usize,
    },
    Dropped {
        payload: String,
        at_token: usize,
    },
    Merged {
        task_id: u64,
        score: f32,
        thought: String,
        injected_rows: usize,
        at_token: usize,
    },
    Rejected {
        task_id: u64,
        score: f32,
        thought: String,
        at_token: usize,
    },
    Failed {
        task_id: u64,
        error: String,
        at_token: usize,
    },
    SynapsePushed {
        version: u64,
        source_len: usize,
        at_token: usize,
    },
}

/// Result of one serving episode.
#[derive(Debug)]
pub struct EpisodeReport {
    pub prompt: String,
    pub text: String,
    pub tokens_generated: usize,
    pub events: Vec<Event>,
    pub elapsed: Duration,
    pub main_tokens_per_sec: f64,
    pub step_latency_p50_ns: f64,
    pub step_latency_p95_ns: f64,
    pub gate: GateStats,
    pub inject: InjectStats,
    pub synapse: SynapseStats,
    /// Step-scheduler gauges (ticks, fused device ops, admissions, parks).
    pub scheduler: StepStats,
    pub memory: MemSnapshot,
    /// Block-pool gauges at episode end (resident vs high-water context).
    pub pool: PoolStats,
}

/// The assembled system.
pub struct WarpCortex {
    pub cfg: CortexConfig,
    pub engine: Arc<Engine>,
    /// The shared KV block pool every agent cache rents from.
    pub pool: Arc<KvPool>,
    pub prism: Arc<Prism>,
    pub synapse: Arc<Synapse>,
    pub gate: Arc<Gate>,
    pub injector: Arc<Injector>,
    /// The unified decode scheduler: every main and side decode step
    /// flows through it as one fused device op per tick.
    pub step: Arc<StepScheduler>,
    pub tracker: Arc<MemoryTracker>,
    pub main_throughput: Throughput,
    pub step_latency: Histogram,
    /// One shared tokenizer for every request path (`prompt_rows`,
    /// `start_main`, `run_episode`) — the per-call `Tokenizer::new()` the
    /// hot paths used to build is hoisted here.
    tokenizer: Tokenizer,
    next_task_id: std::sync::atomic::AtomicU64,
}

impl Drop for WarpCortex {
    fn drop(&mut self) {
        // Join the step-scheduler thread before tearing the rest down: an
        // un-joined thread touching engine state during process exit races
        // the C++ xla_extension teardown (observed as a SIGSEGV at exit).
        self.step.shutdown();
    }
}

impl WarpCortex {
    /// Assemble the system on an existing engine.  The orchestrator adopts
    /// the engine's block pool — there is exactly ONE pool per engine, so
    /// the `cfg.kv_pool` limits and the `/stats` gauges cover every cache,
    /// including those created through `Engine::new_side_cache` by benches
    /// or library callers.  The runtime limits (`max_blocks`,
    /// `retain_free_blocks`) are applied here; the paging granularity
    /// (`block_tokens`) is fixed when the engine is built — use
    /// [`crate::model::Engine::new_with_pool`] to change it.
    pub fn new(engine: Arc<Engine>, cfg: CortexConfig) -> Result<WarpCortex> {
        let tracker = MemoryTracker::new();
        let pool: Arc<KvPool> = engine.pool().clone();
        // A default-valued block_tokens means "whatever the engine uses";
        // only an *explicit* different granularity is an error, because it
        // can't be honored on an already-built engine.
        let default_bt = KvPoolConfig::default().block_tokens;
        if cfg.kv_pool.block_tokens != pool.block_tokens()
            && cfg.kv_pool.block_tokens != default_bt
        {
            bail!(
                "CortexConfig::kv_pool.block_tokens ({}) differs from the engine \
                 pool's ({}); paging granularity is fixed at engine construction — \
                 pass the same KvPoolConfig to Engine::new_with_pool, or leave \
                 block_tokens at its default to adopt the engine's",
                cfg.kv_pool.block_tokens,
                pool.block_tokens()
            );
        }
        pool.set_limits(cfg.kv_pool.max_blocks, cfg.kv_pool.retain_free_blocks);
        let prism = Prism::with_pool(engine.clone(), tracker.clone(), pool.clone());
        let synapse = Synapse::new(tracker.clone());
        let gate = Arc::new(Gate::new(cfg.gate_theta.unwrap_or(engine.gate_theta)));
        let injector = Arc::new(Injector::new(cfg.inject_reserve_rows));
        // The step scheduler's three seams, production-wired:
        //  * spawner — prism registration + synapse seeding per admitted task,
        //  * exec    — the engine's mixed-lane fused batch entry point,
        //  * admit   — pool-occupancy gate: a fresh side cache's worst-case
        //    blocks must still fit under `max_blocks` (0 = unbounded).
        let spawner: AgentSpawner = {
            let step_ctx = StepAgentCtx {
                prism: prism.clone(),
                synapse: synapse.clone(),
                seed_mode: cfg.seed_mode,
                gen_budget: cfg.side_gen_budget,
                sampler: cfg.side_sampler.clone(),
            };
            Arc::new(move |task| SideAgent::spawn(&step_ctx, task))
        };
        let exec: FusedExec = {
            let engine = engine.clone();
            Arc::new(move |main, main_cap, sides, fuse| {
                engine.decode_fused(main, main_cap, sides, fuse)
            })
        };
        let admit: AdmitGate = {
            let pool = pool.clone();
            let bt = pool.block_tokens();
            // Worst-case blocks a side agent can grow to; `can_admit`
            // counts parked (evictable) registry entries as headroom, so a
            // warm prefix registry sitting at the cap doesn't permanently
            // park every new side task.
            let side_blocks_worst = (engine.caps().side_ctx + bt - 1) / bt;
            Arc::new(move || pool.can_admit(side_blocks_worst))
        };
        let step = StepScheduler::new(
            StepConfig {
                batch_width: engine.caps().decode_batch,
                side_ctx: engine.caps().side_ctx,
                max_active: cfg.max_side_agents,
                max_parked: cfg.max_queued_tasks,
                fuse_main: cfg.fuse_main,
            },
            exec,
            spawner,
            admit,
        );
        Ok(WarpCortex {
            cfg,
            engine,
            pool,
            prism,
            synapse,
            gate,
            injector,
            step,
            tracker,
            main_throughput: Throughput::new(),
            step_latency: Histogram::new(),
            tokenizer: Tokenizer::new(),
            next_task_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    fn next_task_id(&self) -> u64 {
        self.next_task_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    /// Rows `prompt` will occupy in a fresh main cache: encoded length
    /// capped by [`WarpCortex::start_main`]'s truncation window
    /// (BOS + the most recent `prefill_len - 1` tokens).  The serve layer
    /// clamps `max_tokens` against this; `start_main` debug-asserts its
    /// truncated ids match it, so the two cannot silently drift.  (The
    /// byte-level tokenizer makes the extra encode O(prompt bytes) —
    /// negligible next to one decode step.)
    pub fn prompt_rows(&self, prompt: &str) -> usize {
        self.tokenizer
            .encode(prompt, true)
            .len()
            .min(self.engine.caps().prefill_len - 1)
    }

    /// Register + prefill a fresh main agent.
    ///
    /// Goes through the prefix-cache-aware `Engine::prefill_shared`: the
    /// first agent of a prompt runs the one cold prefill and registers its
    /// blocks; later agents with the same prefix attach those blocks by
    /// reference and decode only the uncovered tail — zero prefill device
    /// executions and O(1) fresh blocks per warm spawn.
    pub fn start_main(&self, prompt: &str) -> Result<(AgentTicket, Vec<f32>, Vec<f32>)> {
        let mut ticket = self.prism.register(AgentKind::Main)?;
        let max_prompt = self.engine.caps().prefill_len - 1;
        let mut ids = self.tokenizer.encode(prompt, true);
        if ids.len() > max_prompt {
            // keep BOS + the most recent window
            let tail = ids.len() - max_prompt + 1;
            ids = std::iter::once(ids[0]).chain(ids[tail..].iter().copied()).collect();
        }
        // `prompt_rows` is the serve layer's clamp basis — it must predict
        // exactly how many rows this truncation produces.
        debug_assert_eq!(ids.len(), self.prompt_rows(prompt));
        let out = self.engine.prefill_shared(&ids, &mut ticket.kv, Lane::River)?;
        Ok((ticket, out.last_logits, out.hidden_last))
    }

    /// Run one full episode: generate up to `max_tokens` from `prompt`,
    /// routing / gating / injecting along the way.
    pub fn run_episode(&self, prompt: &str, max_tokens: usize) -> Result<EpisodeReport> {
        let started = Instant::now();
        let tk = &self.tokenizer;
        let (mut ticket, mut logits, mut hidden) = self.start_main(prompt)?;
        let mut router = Router::new(self.cfg.router.clone());
        // Triggers already present in the prompt spawn on the first step.
        let mut pending: Vec<Trigger> = router.feed(prompt);

        let mut sampler = Sampler::new(self.cfg.sampler.clone());
        let mut text = String::new();
        let mut events = Vec::new();
        let mut pos = ticket.kv.len() as i32; // text position == cache rows so far
        let mut generated = 0usize;

        while generated < max_tokens && ticket.kv.remaining() > 0 {
            // ── decode one token through the step scheduler ──
            // The step runs at River priority inside the next fused tick
            // (lane 0 of the batch op, or its own op ahead of the side
            // batch once the context outgrows a side lane) — never queued
            // behind side work.
            let t0 = Instant::now();
            let id = sampler.sample(&logits);
            if id == EOS_ID {
                break;
            }
            let out = self.step.main_step(id, pos, &mut ticket.kv)?;
            self.step_latency.record(t0.elapsed());
            self.main_throughput.tick();
            logits = out.logits;
            hidden = out.hidden;
            pos += 1;
            generated += 1;

            let mut new_triggers: Vec<Trigger> = std::mem::take(&mut pending);
            if let Some(b) = tk.decode_one(id) {
                text.push(b as char);
                if let Some(tr) = router.feed_byte(b) {
                    new_triggers.push(tr);
                }
            }

            // ── synapse refresh (Background lane) ──
            let due = generated % self.cfg.synapse_refresh_every == 0;
            let need = !new_triggers.is_empty() && self.synapse.read().is_none();
            if (due || need) && ticket.kv.len() >= self.engine.caps().synapse_k {
                let s = self
                    .engine
                    .synapse_extract(&hidden, &ticket.kv, Lane::Background)?;
                let source_len = s.source_len;
                let version = self.synapse.push(s);
                events.push(Event::SynapsePushed {
                    version,
                    source_len,
                    at_token: generated,
                });
            }

            // ── route triggers to side agents ──
            for tr in new_triggers {
                if self.synapse.read().is_none() {
                    events.push(Event::Dropped {
                        payload: tr.payload,
                        at_token: generated,
                    });
                    continue;
                }
                let task = SideTask {
                    id: self.next_task_id(),
                    role: tr.role,
                    payload: tr.payload.clone(),
                    main_pos: pos,
                    spawned_at: Instant::now(),
                };
                let task_id = task.id;
                if self.step.submit(task) {
                    events.push(Event::Spawned {
                        task_id,
                        tag: tr.tag,
                        payload: tr.payload,
                        at_token: generated,
                    });
                } else {
                    events.push(Event::Dropped {
                        payload: tr.payload,
                        at_token: generated,
                    });
                }
            }

            // ── merge finished side agents (gate + referential injection) ──
            for outcome in self.step.poll_results() {
                self.merge_outcome(outcome, &hidden, &mut ticket, pos, generated, &mut events)?;
            }
        }

        // Final drain pass: give in-flight agents a grace window so every
        // spawned task reaches a terminal event in the report.
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.step.in_flight() > 0 && Instant::now() < deadline {
            if let Some(outcome) = self.step.wait_result(Duration::from_millis(100)) {
                self.merge_outcome(outcome, &hidden, &mut ticket, pos, generated, &mut events)?;
            }
        }
        for outcome in self.step.poll_results() {
            self.merge_outcome(outcome, &hidden, &mut ticket, pos, generated, &mut events)?;
        }

        let elapsed = started.elapsed();
        Ok(EpisodeReport {
            prompt: prompt.to_string(),
            text,
            tokens_generated: generated,
            events,
            elapsed,
            main_tokens_per_sec: generated as f64 / elapsed.as_secs_f64().max(1e-9),
            step_latency_p50_ns: self.step_latency.percentile_ns(50.0),
            step_latency_p95_ns: self.step_latency.percentile_ns(95.0),
            gate: self.gate.stats(),
            inject: self.injector.stats(),
            synapse: self.synapse.stats(),
            scheduler: self.step.stats(),
            memory: self.tracker.snapshot(),
            pool: self.pool.stats(),
        })
    }

    fn merge_outcome(
        &self,
        outcome: SideOutcome,
        main_hidden: &[f32],
        ticket: &mut AgentTicket,
        pos: i32,
        at_token: usize,
        events: &mut Vec<Event>,
    ) -> Result<()> {
        if let Some(err) = &outcome.error {
            events.push(Event::Failed {
                task_id: outcome.task.id,
                error: err.clone(),
                at_token,
            });
            return Ok(());
        }
        if outcome.hidden.is_empty() || outcome.text.trim().is_empty() {
            events.push(Event::Rejected {
                task_id: outcome.task.id,
                score: 0.0,
                thought: outcome.text,
                at_token,
            });
            return Ok(());
        }
        let decision = self.gate.evaluate(main_hidden, &outcome.hidden);
        if !decision.accepted {
            events.push(Event::Rejected {
                task_id: outcome.task.id,
                score: decision.score,
                thought: outcome.text,
                at_token,
            });
            return Ok(());
        }
        let mut injected_rows = 0;
        if self.cfg.inject_enabled {
            let mut thought_ids = vec![crate::text::REF_ID];
            thought_ids.extend(self.tokenizer.encode(&outcome.text, false));
            match self
                .injector
                .inject(&self.engine, &mut ticket.kv, &thought_ids, pos, Lane::Stream)
            {
                Ok(report) => injected_rows = report.rows,
                Err(e) => {
                    log::debug!("injection skipped: {e:#}");
                }
            }
        }
        events.push(Event::Merged {
            task_id: outcome.task.id,
            score: decision.score,
            thought: outcome.text,
            injected_rows,
            at_token,
        });
        Ok(())
    }
}
