//! The Warp-Cortex orchestrator: composes the Prism, Synapse, Router, Gate,
//! Injector and the River & Stream scheduler into the full system of the
//! paper's Figure 1.
//!
//! [`WarpCortex::open_session`] → [`CortexSession`] is the canonical
//! serving loop (`run_episode` is its open/loop/finish wrapper):
//!
//! ```text
//! session 1..S  prefill (River) ─► next_token ──► token stream ─► Router
//!        │              ▲   │                                      │ trigger
//!        ▼              │   ▼ main steps (one per session)         ▼
//!   Synapse push    inject  STEP SCHEDULER ◄─── side agents (pollable
//!   (Background)            one fused device op       token sources)
//!                           per tick: S mains + sides
//! ```
//!
//! Decode scheduling is iteration-level (continuous batching) across
//! *sessions*: every decode step — each session's main step and every
//! side agent's — flows through the [`StepScheduler`], which fuses all
//! runnable agents' next tokens into one `decode_batch` device op per
//! tick.  Fusable main steps ride the leading lanes at River priority
//! while their contexts fit a side lane, and run as their own River ops
//! ahead of the side batch afterwards, preserving the River/Stream lane
//! contract without serializing the op stream.  Sessions admit FIFO
//! (`CortexConfig::max_sessions`, pool-headroom gated with a prefill
//! reservation) and shed with `Busy` beyond the park queue; each
//! session's side-agent outcomes route back to it alone.
//!
//! Prefill is part of the same schedule: when other sessions are already
//! decoding, `open_session` defers the prompt to a
//! [`ChunkedPrefill`] carried inside the session (the prefill→decode
//! state machine), whose block-sized chunks ride the fused tick under
//! [`StepConfig::prefill_budget`] — a long prompt no longer stalls
//! in-flight sessions for its whole length, and its completed blocks
//! register in the prefix registry while it is still prefilling.
//!
//! Context memory is device-resident end to end: every cache write (prefill
//! load, decode append, synapse seed, injection) goes through to the shared
//! pool's device block copies, and every decode step — main-agent River
//! steps and batched side steps alike — ships only a block table.  The
//! episode report's [`PoolStats`] carries the measured `h2d_bytes` /
//! `dev_gathers` gauges, and the prism charges the device copies to
//! `MemKind::DeviceKv`.
//!
//! Identical prompt prefixes are shared copy-on-write through the pool's
//! content-addressed registry: [`WarpCortex::start_main`] goes through
//! `Engine::prefill_shared`, so the first agent of a prompt runs the one
//! cold prefill and every later agent adopts the registered blocks by
//! reference, decoding only the uncovered tail (zero prefill executions,
//! O(1) fresh blocks).  Synapse seeds dedup the same way in `seed_into`.
//! The registry's hit/miss/evict/CoW gauges ride on [`PoolStats`] and the
//! `/stats` endpoint; shared blocks are charged once under
//! `MemKind::SharedKv`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use super::agent::{SideAgent, SideOutcome, SideTask, StepAgentCtx};
use super::gate::{Gate, GateStats};
use super::inject::{InjectStats, Injector};
use super::memory::{MemSnapshot, MemoryTracker};
use super::prism::{AgentKind, AgentTicket, Prism};
use super::router::{Router, RouterConfig, Trigger};
use super::step::{
    AdmitGate, AgentSpawner, FusedExec, SessionPermit, StepConfig, StepScheduler, StepSeams,
    StepStats,
};
use super::store::{SessionCheckpoint, SessionStore, StoreError};
use super::synapse::{Synapse, SynapseStats};
use crate::metrics::{Histogram, Throughput};
use crate::model::{
    BlockReservation, ChunkedPrefill, Engine, KvPool, KvPoolConfig, PoolStats,
};
use crate::runtime::Lane;
use crate::text::{Sampler, SamplerConfig, Tokenizer, EOS_ID};
use crate::util::Json;

/// Orchestrator configuration.
#[derive(Debug, Clone)]
pub struct CortexConfig {
    /// Model config name (must be loaded on the device).
    pub model: String,
    /// Max concurrently *decoding* side agents (step-scheduler active set).
    pub max_side_agents: usize,
    /// Additional parked tasks beyond the active ones (admission queue).
    pub max_queued_tasks: usize,
    /// Refresh the synapse every this many main-agent tokens.
    pub synapse_refresh_every: usize,
    /// Side-agent thought budget (generated tokens).
    pub side_gen_budget: usize,
    /// Enable Referential Injection of gate-accepted thoughts.
    pub inject_enabled: bool,
    /// Rows always reserved for main-agent generation (injection headroom).
    pub inject_reserve_rows: usize,
    /// Validation-gate threshold θ (None = artifact default, 0.5).
    pub gate_theta: Option<f32>,
    /// Main-agent sampling.
    pub sampler: SamplerConfig,
    /// Side-agent sampling.
    pub side_sampler: SamplerConfig,
    /// Legacy linger window of the [`super::Batcher`] API.  The serving
    /// path batches at iteration level through the step scheduler and
    /// never lingers; kept for callers assembling the legacy batcher
    /// directly.
    pub batch_linger: Duration,
    /// Ride main steps on the leading lanes of the fused batch op while
    /// their contexts fit a side-capacity lane (one device op per tick).
    /// Off = every main step runs as its own River op ahead of the side
    /// batch (strictest lane isolation).
    pub fuse_main: bool,
    /// Concurrent serving sessions (main streams) sharing the fused tick
    /// loop.  `open_session` calls beyond this park FIFO until a session
    /// closes.
    pub max_sessions: usize,
    /// Sessions allowed to wait for admission before `open_session`
    /// rejects outright (load shedding — the serve layer answers 503).
    pub max_parked_sessions: usize,
    /// Cross-session gather window: when fewer main steps are queued than
    /// there are admitted sessions, the tick loop waits up to this long
    /// for the other sessions' concurrent steps so S sessions share one
    /// fused device op.  Negligible against a real device op; zero
    /// disables gathering.
    pub main_gather: Duration,
    /// Admit prompts as *chunked* prefill when other sessions are already
    /// decoding: the prompt teacher-forces through the shared fused tick
    /// under [`CortexConfig::prefill_budget`] instead of running one
    /// monolithic prefill op that would stall every concurrent stream's
    /// inter-token latency.  A session opening into an idle system still
    /// takes the monolithic path (one prefill op beats N per-token lanes
    /// when nobody is waiting behind it).
    pub chunked_prefill: bool,
    /// Per-tick cap on teacher-forced prefill lanes riding the fused tick
    /// ([`super::step::StepConfig::prefill_budget`]) — the TTFT-vs-TPOT
    /// dial under admission storms.  Clamped to ≥ 1.
    pub prefill_budget: usize,
    pub router: RouterConfig,
    /// Side-cache seeding (Full, or the §6.2 Coarse/Adaptive extensions).
    pub seed_mode: crate::cortex::synapse::SeedMode,
    /// Shared KV block pool knobs.  The orchestrator adopts the engine's
    /// pool (one pool per engine) and applies the runtime limits here:
    /// capacity ceiling (`max_blocks`, 0 = unbounded) and reclaim policy
    /// (`retain_free_blocks`).  `block_tokens` must match the engine pool's
    /// paging granularity (fixed at engine construction via
    /// `Engine::new_with_pool`); a mismatch is rejected at assembly.
    pub kv_pool: KvPoolConfig,
    /// Durable session store file ([`super::store`]).  `None` disables the
    /// fourth memory tier entirely: no checkpoints, no
    /// `POST /sessions/{id}/resume`, and admission under pool pressure
    /// sheds (503) instead of preempting parked sessions to disk.
    pub store_path: Option<std::path::PathBuf>,
    /// Auto-checkpoint a session's durable record whenever it parks to the
    /// cold host slab ([`CortexSession::park_to_host`]), so a parked
    /// session is crash-recoverable the moment it goes quiet.
    /// [`CortexSession::hibernate`] always checkpoints regardless — a
    /// hibernated session frees its admission slot, so the record is the
    /// only path back.
    pub checkpoint_on_park: bool,
    /// Let the serve layer hibernate (checkpoint + park) a streaming
    /// session whose client disconnected mid-stream, instead of cancelling
    /// it — the client can reconnect through `POST /sessions/{id}/resume`
    /// and continue from the exact token it left off.
    pub checkpoint_on_disconnect: bool,
}

impl Default for CortexConfig {
    fn default() -> Self {
        CortexConfig {
            model: "small".into(),
            max_side_agents: 4,
            max_queued_tasks: 16,
            synapse_refresh_every: 32,
            side_gen_budget: 24,
            inject_enabled: true,
            inject_reserve_rows: 64,
            gate_theta: None,
            sampler: SamplerConfig::default(),
            side_sampler: SamplerConfig {
                temperature: 0.7,
                ..SamplerConfig::default()
            },
            batch_linger: Duration::from_micros(500),
            fuse_main: true,
            max_sessions: 8,
            max_parked_sessions: 32,
            main_gather: Duration::from_micros(200),
            chunked_prefill: true,
            prefill_budget: 2,
            router: RouterConfig::default(),
            seed_mode: crate::cortex::synapse::SeedMode::Full,
            kv_pool: KvPoolConfig::default(),
            store_path: None,
            checkpoint_on_park: true,
            checkpoint_on_disconnect: true,
        }
    }
}

/// One recorded coordination event (for reports and the council example).
#[derive(Debug, Clone)]
pub enum Event {
    Spawned {
        task_id: u64,
        tag: String,
        payload: String,
        at_token: usize,
    },
    Dropped {
        payload: String,
        at_token: usize,
    },
    Merged {
        task_id: u64,
        score: f32,
        thought: String,
        injected_rows: usize,
        at_token: usize,
    },
    Rejected {
        task_id: u64,
        score: f32,
        thought: String,
        at_token: usize,
    },
    Failed {
        task_id: u64,
        error: String,
        at_token: usize,
    },
    SynapsePushed {
        version: u64,
        source_len: usize,
        at_token: usize,
    },
}

/// Result of one serving episode.
#[derive(Debug)]
pub struct EpisodeReport {
    pub prompt: String,
    pub text: String,
    pub tokens_generated: usize,
    pub events: Vec<Event>,
    pub elapsed: Duration,
    pub main_tokens_per_sec: f64,
    pub step_latency_p50_ns: f64,
    pub step_latency_p95_ns: f64,
    pub gate: GateStats,
    pub inject: InjectStats,
    pub synapse: SynapseStats,
    /// Step-scheduler gauges (ticks, fused device ops, admissions, parks).
    pub scheduler: StepStats,
    pub memory: MemSnapshot,
    /// Block-pool gauges at episode end (resident vs high-water context).
    pub pool: PoolStats,
}

impl Event {
    /// Wire shape of one coordination event (the `/generate` `events`
    /// array).
    pub fn to_json(&self) -> Json {
        match self {
            Event::Spawned { task_id, tag, payload, at_token } => Json::obj()
                .with("type", "spawned")
                .with("task", *task_id as i64)
                .with("tag", tag.as_str())
                .with("payload", payload.as_str())
                .with("at_token", *at_token),
            Event::Dropped { payload, at_token } => Json::obj()
                .with("type", "dropped")
                .with("payload", payload.as_str())
                .with("at_token", *at_token),
            Event::Merged { task_id, score, thought, injected_rows, at_token } => Json::obj()
                .with("type", "merged")
                .with("task", *task_id as i64)
                .with("score", *score as f64)
                .with("thought", thought.as_str())
                .with("injected_rows", *injected_rows)
                .with("at_token", *at_token),
            Event::Rejected { task_id, score, thought, at_token } => Json::obj()
                .with("type", "rejected")
                .with("task", *task_id as i64)
                .with("score", *score as f64)
                .with("thought", thought.as_str())
                .with("at_token", *at_token),
            Event::Failed { task_id, error, at_token } => Json::obj()
                .with("type", "failed")
                .with("task", *task_id as i64)
                .with("error", error.as_str())
                .with("at_token", *at_token),
            Event::SynapsePushed { version, source_len, at_token } => Json::obj()
                .with("type", "synapse")
                .with("version", *version)
                .with("source_len", *source_len)
                .with("at_token", *at_token),
        }
    }
}

impl EpisodeReport {
    /// Wire shape of the episode summary: the non-streaming `/generate`
    /// response body, and (with `"done": true` added) the trailing chunk
    /// of a streaming one.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .with("text", self.text.as_str())
            .with("tokens", self.tokens_generated)
            .with("elapsed_ms", self.elapsed.as_secs_f64() * 1e3)
            .with("tokens_per_sec", self.main_tokens_per_sec)
            .with(
                "events",
                Json::Arr(self.events.iter().map(Event::to_json).collect()),
            )
    }
}

/// Why [`WarpCortex::open_session`] refused.
#[derive(Debug)]
pub enum SessionError {
    /// Admission refused (session queue full or shutdown): shed load and
    /// retry later — the serve layer answers 503.
    Busy(String),
    /// Episode bring-up failed (registration, prefill).
    Failed(anyhow::Error),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::Busy(m) => write!(f, "session admission refused: {m}"),
            SessionError::Failed(e) => write!(f, "session failed to open: {e:#}"),
        }
    }
}

// Manual bridge instead of `std::error::Error` so anyhow's blanket
// `From<E: Error>` impl does not conflict.
impl From<SessionError> for anyhow::Error {
    fn from(e: SessionError) -> anyhow::Error {
        match e {
            SessionError::Busy(m) => anyhow::anyhow!("session admission refused: {m}"),
            SessionError::Failed(e) => e,
        }
    }
}

/// Why [`WarpCortex::resume_session`] refused.
#[derive(Debug)]
pub enum ResumeError {
    /// No retained checkpoint under this id (never checkpointed, already
    /// resumed, or lost to contained corruption at recovery) — the serve
    /// layer answers 404.
    Unknown(u64),
    /// The record existed but failed its CRC or decode; it has been
    /// dropped (counted in `corrupt_records_skipped`) — 500.
    Corrupt(String),
    /// Admission or bring-up failed the same ways [`WarpCortex::open_session`]
    /// can — `Busy` is a retryable 503 and the record stays retained.
    Session(SessionError),
}

impl std::fmt::Display for ResumeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResumeError::Unknown(id) => write!(f, "no resumable checkpoint for session {id}"),
            ResumeError::Corrupt(m) => write!(f, "checkpoint unrecoverable: {m}"),
            ResumeError::Session(e) => write!(f, "resume re-admission failed: {e}"),
        }
    }
}

impl From<ResumeError> for anyhow::Error {
    fn from(e: ResumeError) -> anyhow::Error {
        anyhow::anyhow!("{e}")
    }
}

/// The assembled system.
pub struct WarpCortex {
    pub cfg: CortexConfig,
    pub engine: Arc<Engine>,
    /// The shared KV block pool every agent cache rents from.
    pub pool: Arc<KvPool>,
    pub prism: Arc<Prism>,
    pub synapse: Arc<Synapse>,
    pub gate: Arc<Gate>,
    pub injector: Arc<Injector>,
    /// The unified decode scheduler: every main and side decode step
    /// flows through it as one fused device op per tick.
    pub step: Arc<StepScheduler>,
    /// The durable session store (fourth memory tier) when
    /// `CortexConfig::store_path` is set: checkpoint/resume records plus
    /// the resident parked tickets that preempt-to-disk can sacrifice.
    pub store: Option<Arc<SessionStore>>,
    pub tracker: Arc<MemoryTracker>,
    pub main_throughput: Throughput,
    pub step_latency: Histogram,
    /// One shared tokenizer for every request path (`prompt_rows`,
    /// `start_main`, `run_episode`) — the per-call `Tokenizer::new()` the
    /// hot paths used to build is hoisted here.
    tokenizer: Tokenizer,
    next_task_id: std::sync::atomic::AtomicU64,
}

impl Drop for WarpCortex {
    fn drop(&mut self) {
        // Join the step-scheduler thread before tearing the rest down: an
        // un-joined thread touching engine state during process exit races
        // the C++ xla_extension teardown (observed as a SIGSEGV at exit).
        self.step.shutdown();
    }
}

impl WarpCortex {
    /// Assemble the system on an existing engine.  The orchestrator adopts
    /// the engine's block pool — there is exactly ONE pool per engine, so
    /// the `cfg.kv_pool` limits and the `/stats` gauges cover every cache,
    /// including those created through `Engine::new_side_cache` by benches
    /// or library callers.  The runtime limits (`max_blocks`,
    /// `retain_free_blocks`) are applied here; the paging granularity
    /// (`block_tokens`) is fixed when the engine is built — use
    /// [`crate::model::Engine::new_with_pool`] to change it.
    pub fn new(engine: Arc<Engine>, cfg: CortexConfig) -> Result<WarpCortex> {
        let tracker = MemoryTracker::new();
        let pool: Arc<KvPool> = engine.pool().clone();
        // A default-valued block_tokens means "whatever the engine uses";
        // only an *explicit* different granularity is an error, because it
        // can't be honored on an already-built engine.
        let default_bt = KvPoolConfig::default().block_tokens;
        if cfg.kv_pool.block_tokens != pool.block_tokens()
            && cfg.kv_pool.block_tokens != default_bt
        {
            bail!(
                "CortexConfig::kv_pool.block_tokens ({}) differs from the engine \
                 pool's ({}); paging granularity is fixed at engine construction — \
                 pass the same KvPoolConfig to Engine::new_with_pool, or leave \
                 block_tokens at its default to adopt the engine's",
                cfg.kv_pool.block_tokens,
                pool.block_tokens()
            );
        }
        pool.set_limits(cfg.kv_pool.max_blocks, cfg.kv_pool.retain_free_blocks);
        // Tiering knobs ride the same config: parked registry entries
        // demote to int8 (`quantize_parked`) and parked sessions /
        // refcount-0 entries may spill to the cold host slab
        // (`host_slab_blocks`), so admission sheds only when BOTH tiers
        // are exhausted.
        pool.set_tiering(cfg.kv_pool.quantize_parked, cfg.kv_pool.host_slab_blocks);
        let prism = Prism::with_pool(engine.clone(), tracker.clone(), pool.clone());
        let synapse = Synapse::new(tracker.clone());
        // The durable tier opens (and crash-recovers) before any seam can
        // observe it: the admission gate and the preempt path both hold a
        // reference from the first tick.
        let store = match &cfg.store_path {
            Some(path) => Some(Arc::new(SessionStore::open(path)?)),
            None => None,
        };
        let gate = Arc::new(Gate::new(cfg.gate_theta.unwrap_or(engine.gate_theta)));
        let injector = Arc::new(Injector::new(cfg.inject_reserve_rows));
        // The step scheduler's three seams, production-wired:
        //  * spawner — prism registration + synapse seeding per admitted task,
        //  * exec    — the engine's mixed-lane fused batch entry point,
        //  * admit   — pool-occupancy gate: a fresh side cache's worst-case
        //    blocks must still fit under `max_blocks` (0 = unbounded).
        let spawner: AgentSpawner = {
            let step_ctx = StepAgentCtx {
                prism: prism.clone(),
                synapse: synapse.clone(),
                seed_mode: cfg.seed_mode,
                gen_budget: cfg.side_gen_budget,
                sampler: cfg.side_sampler.clone(),
            };
            Arc::new(move |task| SideAgent::spawn(&step_ctx, task))
        };
        let exec: FusedExec = {
            let engine = engine.clone();
            Arc::new(move |mains, sides, fuse| engine.decode_fused(mains, sides, fuse))
        };
        let admit: AdmitGate = {
            let pool = pool.clone();
            let bt = pool.block_tokens();
            // Worst-case blocks a side agent can grow to; `can_admit`
            // counts parked (evictable) registry entries as headroom, so a
            // warm prefix registry sitting at the cap doesn't permanently
            // park every new side task.
            let side_blocks_worst = (engine.caps().side_ctx + bt - 1) / bt;
            Arc::new(move || pool.can_admit(side_blocks_worst))
        };
        let session_admit: AdmitGate = {
            let pool = pool.clone();
            let store = store.clone();
            let bt = pool.block_tokens();
            // Session admission guards the prefill burst: a fresh session's
            // prompt can occupy up to `prefill_len` rows (+1 block of slack
            // for its first generated rows).  Growth beyond that is
            // backpressured per-step by the pool's own rent path.  With a
            // durable store, resident parked sessions are a fourth
            // admission tier behind `can_admit`'s hot/evictable/host
            // headroom: they can be preempted to disk, so their presence
            // alone admits the arrival — `open_session`'s reservation loop
            // does the actual preemption on the caller thread (this gate
            // runs under the scheduler's session-table lock and must stay
            // lock-free and IO-free).
            let prefill_blocks = (engine.caps().prefill_len + bt - 1) / bt + 1;
            Arc::new(move || {
                pool.can_admit(prefill_blocks)
                    || store.as_ref().is_some_and(|s| s.parked_resident() > 0)
            })
        };
        let step = StepScheduler::new(
            StepConfig {
                batch_width: engine.caps().decode_batch,
                side_ctx: engine.caps().side_ctx,
                max_active: cfg.max_side_agents,
                max_parked: cfg.max_queued_tasks,
                fuse_main: cfg.fuse_main,
                max_sessions: cfg.max_sessions,
                max_parked_sessions: cfg.max_parked_sessions,
                main_gather: cfg.main_gather,
                prefill_budget: cfg.prefill_budget.max(1),
            },
            StepSeams {
                exec,
                spawner,
                admit,
                session_admit,
                // Debug builds re-prove the pool conservation laws at every
                // tick boundary; release ticks skip the check entirely.
                invariants: Some({
                    let pool = pool.clone();
                    Arc::new(move || pool.check_invariants())
                }),
            },
        );
        Ok(WarpCortex {
            cfg,
            engine,
            pool,
            prism,
            synapse,
            gate,
            injector,
            step,
            store,
            tracker,
            main_throughput: Throughput::new(),
            step_latency: Histogram::new(),
            tokenizer: Tokenizer::new(),
            next_task_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    fn next_task_id(&self) -> u64 {
        self.next_task_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    /// Rows `prompt` will occupy in a fresh main cache: encoded length
    /// capped by [`WarpCortex::start_main`]'s truncation window
    /// (BOS + the most recent `prefill_len - 1` tokens).  Library callers'
    /// capacity-planning helper; the request hot path itself encodes ONCE
    /// via `truncated_prompt_ids` (which debug-asserts against this, so
    /// the two cannot silently drift).
    pub fn prompt_rows(&self, prompt: &str) -> usize {
        self.tokenizer
            .encode(prompt, true)
            .len()
            .min(self.engine.caps().prefill_len - 1)
    }

    /// Encode + truncate a prompt to what the prefill window holds
    /// (BOS + the most recent `prefill_len - 1` tokens): the ONE encode a
    /// session needs — its length sizes the admission reservation and the
    /// ids feed the prefill, so the hot path never tokenizes twice.
    fn truncated_prompt_ids(&self, prompt: &str) -> Vec<i32> {
        let max_prompt = self.engine.caps().prefill_len - 1;
        let mut ids = self.tokenizer.encode(prompt, true);
        if ids.len() > max_prompt {
            // keep BOS + the most recent window
            let tail = ids.len() - max_prompt + 1;
            ids = std::iter::once(ids[0]).chain(ids[tail..].iter().copied()).collect();
        }
        // `prompt_rows` is the public planning figure — it must predict
        // exactly how many rows this truncation produces.
        debug_assert_eq!(ids.len(), self.prompt_rows(prompt));
        ids
    }

    /// Register + prefill a fresh main agent.
    ///
    /// Goes through the prefix-cache-aware `Engine::prefill_shared`: the
    /// first agent of a prompt runs the one cold prefill and registers its
    /// blocks; later agents with the same prefix attach those blocks by
    /// reference and decode only the uncovered tail — zero prefill device
    /// executions and O(1) fresh blocks per warm spawn.
    pub fn start_main(&self, prompt: &str) -> Result<(AgentTicket, Vec<f32>, Vec<f32>)> {
        let ids = self.truncated_prompt_ids(prompt);
        self.start_main_ids(&ids)
    }

    fn start_main_ids(&self, ids: &[i32]) -> Result<(AgentTicket, Vec<f32>, Vec<f32>)> {
        let mut ticket = self.prism.register(AgentKind::Main)?;
        let out = self.engine.prefill_shared(ids, &mut ticket.kv, Lane::River)?;
        Ok((ticket, out.last_logits, out.hidden_last))
    }

    /// Reserve `blocks` of pool headroom, preempting hibernated-resident
    /// sessions to disk (coldest first) until the reservation fits or no
    /// preemptable session remains.  This is the preempt-to-disk admission
    /// tier: a parked session's ticket drops (its record is already
    /// durable — resume rebuilds it from the file), its blocks return to
    /// the pool, and the arrival that would have shed with 503 admits
    /// instead.  Runs on the caller thread — never under a scheduler lock
    /// and never inside the fused tick.
    fn reserve_or_preempt(&self, blocks: usize) -> Option<BlockReservation<'_>> {
        loop {
            match self.pool.try_reserve(blocks) {
                Some(rsv) => return Some(rsv),
                // Bounded: every iteration drops one resident ticket.
                None => match &self.store {
                    Some(store) if store.preempt_coldest() => continue,
                    _ => return None,
                },
            }
        }
    }

    /// Open one serving session: admit it (blocking FIFO when the session
    /// slots or pool headroom are saturated), run the prefix-shared
    /// prefill, and return the incremental episode state machine.  S open
    /// sessions' main steps fuse into shared device ticks — this is the
    /// multi-session serving entry point behind streaming `/generate`.
    ///
    /// Dropping the returned session without [`CortexSession::finish`]
    /// cancels it: the admission slot frees for the next parked session,
    /// the main cache's blocks return to the pool, and any undelivered
    /// side outcomes are discarded.
    pub fn open_session(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> std::result::Result<CortexSession<'_>, SessionError> {
        let permit = self
            .step
            .open_session()
            .map_err(|d| SessionError::Busy(d.to_string()))?;
        // Atomically reserve the prefill burst between admission and
        // prefill: the admission gate and this check race across sessions,
        // so the reservation re-validates headroom under the pool lock — N
        // simultaneously admitted sessions cannot all pass the gate and
        // then collectively exhaust the pool; the loser sheds as Busy
        // (retryable 503) instead of failing mid-prefill.  One encode
        // serves both the reservation sizing and the prefill.
        let ids = self.truncated_prompt_ids(prompt);
        let bt = self.pool.block_tokens();
        let rsv = match self.reserve_or_preempt(ids.len() / bt + 1) {
            Some(rsv) => rsv,
            None => {
                // Reclassify this admission as a shed so the `sessions`
                // gauges count the 503, not a phantom completed session.
                permit.shed();
                return Err(SessionError::Busy(
                    "kv pool headroom claimed by a concurrent admission".into(),
                ));
            }
        };
        // Chunked admission (the bounded-TTFT path): when other sessions
        // are already decoding, the prompt enters as teacher-forced lanes
        // that ride the shared fused tick under the per-tick prefill
        // budget — a long prompt can no longer stall every concurrent
        // stream behind one monolithic prefill op.  Alone in the system,
        // the monolithic path wins (one device op for the whole prompt),
        // so chunking only engages with company.
        let use_chunked = self.cfg.chunked_prefill && self.step.session_stats().active > 1;
        let (ticket, logits, hidden, prefill) = if use_chunked {
            let opened = (|| {
                let mut ticket = self.prism.register(AgentKind::Main)?;
                let cp = ChunkedPrefill::begin(&ids, &mut ticket.kv)?;
                Ok::<_, anyhow::Error>((ticket, cp))
            })();
            let (ticket, cp) = opened.map_err(SessionError::Failed)?;
            // The reservation rides into the session: its rows are rented
            // chunk-by-chunk across the coming ticks, so releasing the
            // headroom now would let a concurrent admission claim it and
            // fail this session mid-prefill instead of shedding cleanly.
            (ticket, Vec::new(), Vec::new(), Some((cp, rsv)))
        } else {
            let opened = self.start_main_ids(&ids);
            drop(rsv); // the real blocks are rented (or the prefill failed)
            let (ticket, logits, hidden) = opened.map_err(SessionError::Failed)?;
            (ticket, logits, hidden, None)
        };
        let mut router = Router::new(self.cfg.router.clone());
        // Triggers already present in the prompt spawn on the first step.
        let pending: Vec<Trigger> = router.feed(prompt);
        Ok(CortexSession {
            pos: ticket.kv.len() as i32, // text position == cache rows so far
            cx: self,
            // A fresh session's durable identity is its first permit id;
            // resume issues new permits but keeps this id, so the client
            // handle survives hibernation cycles.
            durable_id: permit.id(),
            permit,
            ticket,
            prefill,
            router,
            sampler: Sampler::new(self.cfg.sampler.clone()),
            prompt: prompt.to_string(),
            prompt_ids: ids,
            logits,
            hidden,
            pending,
            text: String::new(),
            events: Vec::new(),
            generated: 0,
            max_tokens,
            outstanding: 0,
            started: Instant::now(),
            done: false,
        })
    }

    /// Resume a checkpointed session by its durable id: re-admit it
    /// through the scheduler (a fresh permit — the durable id survives),
    /// rebuild its context, and return a live [`CortexSession`] whose next
    /// token is bit-identical to what the never-interrupted session would
    /// have produced (same logits, same sampler RNG position).
    ///
    /// Context rebuild is tiered like everything else:
    ///
    /// 1. **resident fast path** — the session hibernated in this process
    ///    and escaped preemption: its parked ticket pages back from the
    ///    cold host slab, no device recompute at all;
    /// 2. **registry-covered rebuild** — the record's shared prefix
    ///    re-attaches from the content-addressed registry by hash chain
    ///    and only the private tail rows load from the file — zero
    ///    re-prefill device ops;
    /// 3. **full rebuild** — the registry no longer covers the prefix
    ///    (evicted after preempt-to-disk dropped the last reference): one
    ///    deterministic re-prefill of the prompt re-registers it, then the
    ///    post-prompt tail loads from the file.
    ///
    /// `take` is single-use: a successful (or corrupt) resume consumes the
    /// record; `Busy` re-retains it so the client can retry.
    pub fn resume_session(
        &self,
        id: u64,
    ) -> std::result::Result<CortexSession<'_>, ResumeError> {
        let store = match &self.store {
            Some(s) => s.clone(),
            None => return Err(ResumeError::Unknown(id)),
        };
        // Re-admit before touching the record: a Busy here must not
        // consume the single-use checkpoint.
        let permit = self
            .step
            .open_session()
            .map_err(|d| ResumeError::Session(SessionError::Busy(d.to_string())))?;
        let rt = match store.take(id) {
            Ok(rt) => rt,
            Err(e) => {
                permit.shed();
                return Err(match e {
                    StoreError::Unknown(id) => ResumeError::Unknown(id),
                    other => ResumeError::Corrupt(other.to_string()),
                });
            }
        };
        let cp = rt.checkpoint;
        // Tier 1: the hibernated ticket is still resident in this process.
        let resident = rt
            .resident
            .and_then(|b| b.downcast::<AgentTicket>().ok().map(|b| *b));
        let ticket = match resident {
            Some(mut t) => match t.kv.resume_from_host() {
                Ok(_) => Ok(t),
                Err(e) => {
                    // Host-slab page-in failed; the ticket is unusable but
                    // the record still rebuilds — fall through to tier 2/3
                    // after re-retaining it would double-count, so rebuild
                    // directly from the in-hand checkpoint.
                    log::debug!("resident resume page-in failed, rebuilding: {e:#}");
                    drop(t);
                    self.rebuild_ticket(&store, &cp)
                }
            },
            None => self.rebuild_ticket(&store, &cp),
        };
        let ticket = match ticket {
            Ok(t) => t,
            Err(e) => {
                permit.shed();
                return Err(e);
            }
        };
        debug_assert_eq!(ticket.kv.len(), cp.total_rows as usize);
        // Restore the generation state machine exactly: sampler RNG +
        // repetition window, last logits/hidden, positions.  The router
        // re-feeds the transcript to rebuild its byte-level matcher state;
        // its triggers already fired in the previous life and are
        // discarded (their side agents were drained or cancelled then).
        let mut router = Router::new(self.cfg.router.clone());
        let _ = router.feed(&cp.prompt);
        for b in cp.text.bytes() {
            let _ = router.feed_byte(b);
        }
        let sampler = Sampler::restore(self.cfg.sampler.clone(), cp.rng_state, cp.recent);
        Ok(CortexSession {
            pos: cp.pos as i32,
            cx: self,
            durable_id: id,
            permit,
            ticket,
            prefill: None,
            router,
            sampler,
            prompt: cp.prompt,
            prompt_ids: cp.prompt_ids,
            logits: cp.logits,
            hidden: cp.hidden,
            pending: Vec::new(),
            text: cp.text,
            events: Vec::new(),
            generated: cp.generated as usize,
            max_tokens: cp.max_tokens as usize,
            outstanding: 0,
            started: Instant::now(),
            done: false,
        })
    }

    /// Tiers 2 and 3 of [`WarpCortex::resume_session`]: rebuild a context
    /// from its durable record.  On `Busy` the record is re-checkpointed
    /// (stays retained — the conservation ledger counts the original take
    /// as a resume and this as a fresh checkpoint superseding nothing).
    fn rebuild_ticket(
        &self,
        store: &SessionStore,
        cp: &SessionCheckpoint,
    ) -> std::result::Result<AgentTicket, ResumeError> {
        let bt = self.pool.block_tokens();
        let row = self.pool.row();
        let n_layers = self.pool.n_layers();
        let total_rows = cp.total_rows as usize;
        let shared_rows = cp.shared_rows as usize;
        let prompt_len = cp.prompt_ids.len();
        let tail_rows = match total_rows.checked_sub(shared_rows) {
            Some(t) => t,
            None => {
                return Err(ResumeError::Corrupt(format!(
                    "shared_rows {shared_rows} exceeds total_rows {total_rows}"
                )))
            }
        };
        if shared_rows % bt != 0
            || shared_rows > prompt_len
            || prompt_len > total_rows
            || cp.k_tail.len() != n_layers * tail_rows * row
            || cp.v_tail.len() != cp.k_tail.len()
        {
            return Err(ResumeError::Corrupt(format!(
                "checkpoint geometry inconsistent: shared {shared_rows} / prompt \
                 {prompt_len} / total {total_rows} rows, tail {} + {} floats",
                cp.k_tail.len(),
                cp.v_tail.len()
            )));
        }
        // Headroom for the rebuilt context, preempting parked sessions to
        // disk like any other admission.
        let rsv = match self.reserve_or_preempt(total_rows / bt + 1) {
            Some(rsv) => rsv,
            None => {
                let _ = store.checkpoint(cp); // keep the session resumable
                return Err(ResumeError::Session(SessionError::Busy(
                    "kv pool headroom claimed by concurrent admissions".into(),
                )));
            }
        };
        let shared_blocks = shared_rows / bt;
        let attempt = (|| -> Result<AgentTicket> {
            // Tier 2: re-attach the shared prefix by hash chain — the
            // checkpoint stored no shared bytes, just the chain keys.
            let mut ticket = self.prism.register(AgentKind::Main)?;
            let hashes = self
                .pool
                .prefix_hashes(crate::model::PROMPT_CHAIN_SALT, &cp.prompt_ids);
            let covered = if shared_blocks > 0 && shared_blocks <= hashes.len() {
                ticket
                    .kv
                    .attach_shared_prefix(&hashes[..shared_blocks], &cp.prompt_ids[..shared_rows])
                    .unwrap_or(0)
            } else {
                0
            };
            if covered == shared_rows {
                ticket.kv.append_rows(tail_rows, &cp.k_tail, &cp.v_tail)?;
                return Ok(ticket);
            }
            // Tier 3: the registry evicted the prefix — one deterministic
            // re-prefill reproduces (and re-registers) the prompt rows
            // bit-identically, then only the post-prompt tail loads from
            // the record (skipping the prompt rows the prefill re-covered).
            drop(ticket);
            let (mut ticket, _logits, _hidden) = self.start_main_ids(&cp.prompt_ids)?;
            let skip = prompt_len - shared_rows;
            let n_app = total_rows - prompt_len;
            let seg = tail_rows * row;
            let mut k = Vec::with_capacity(n_layers * n_app * row);
            let mut v = Vec::with_capacity(n_layers * n_app * row);
            for layer in 0..n_layers {
                let base = layer * seg;
                k.extend_from_slice(&cp.k_tail[base + skip * row..base + seg]);
                v.extend_from_slice(&cp.v_tail[base + skip * row..base + seg]);
            }
            ticket.kv.append_rows(n_app, &k, &v)?;
            Ok(ticket)
        })();
        drop(rsv); // the context's rows are rented (or the rebuild failed)
        match attempt {
            Ok(t) => Ok(t),
            Err(e) => {
                let _ = store.checkpoint(cp); // keep the session resumable
                Err(ResumeError::Session(SessionError::Failed(e)))
            }
        }
    }

    /// Run one full episode: generate up to `max_tokens` from `prompt`,
    /// routing / gating / injecting along the way.  Thin wrapper over the
    /// session API — one `open_session`, a token loop, one `finish`.
    pub fn run_episode(&self, prompt: &str, max_tokens: usize) -> Result<EpisodeReport> {
        let mut session = self
            .open_session(prompt, max_tokens)
            .map_err(anyhow::Error::from)?;
        while session.next_token()?.is_some() {}
        session.finish()
    }

    fn merge_outcome(
        &self,
        outcome: SideOutcome,
        main_hidden: &[f32],
        ticket: &mut AgentTicket,
        pos: i32,
        at_token: usize,
        events: &mut Vec<Event>,
    ) -> Result<()> {
        if let Some(err) = &outcome.error {
            events.push(Event::Failed {
                task_id: outcome.task.id,
                error: err.clone(),
                at_token,
            });
            return Ok(());
        }
        if outcome.hidden.is_empty() || outcome.text.trim().is_empty() {
            events.push(Event::Rejected {
                task_id: outcome.task.id,
                score: 0.0,
                thought: outcome.text,
                at_token,
            });
            return Ok(());
        }
        let decision = self.gate.evaluate(main_hidden, &outcome.hidden);
        if !decision.accepted {
            events.push(Event::Rejected {
                task_id: outcome.task.id,
                score: decision.score,
                thought: outcome.text,
                at_token,
            });
            return Ok(());
        }
        let mut injected_rows = 0;
        if self.cfg.inject_enabled {
            let mut thought_ids = vec![crate::text::REF_ID];
            thought_ids.extend(self.tokenizer.encode(&outcome.text, false));
            match self
                .injector
                .inject(&self.engine, &mut ticket.kv, &thought_ids, pos, Lane::Stream)
            {
                Ok(report) => injected_rows = report.rows,
                Err(e) => {
                    log::debug!("injection skipped: {e:#}");
                }
            }
        }
        events.push(Event::Merged {
            task_id: outcome.task.id,
            score: decision.score,
            thought: outcome.text,
            injected_rows,
            at_token,
        });
        Ok(())
    }
}

/// One live serving session (the tentpole of the multi-session refactor):
/// the episode loop turned into an incremental state machine so N
/// concurrent requests can each advance one token at a time while the
/// [`StepScheduler`] fuses their steps into shared device ticks.
///
/// Per [`CortexSession::next_token`] call: sample from the last logits,
/// run one main step (fused with every other session's pending step and
/// the side batch), feed the router, refresh the synapse on schedule,
/// spawn triggered side agents (tagged with this session's id so their
/// outcomes route back here only), and merge any of *this session's*
/// finished side agents.  [`CortexSession::finish`] drains the session's
/// in-flight side agents and produces the [`EpisodeReport`].
///
/// Dropping the session mid-stream (a disconnected streaming client)
/// cancels it: the prism ticket returns the cache blocks, the permit
/// frees the admission slot, and undelivered outcomes are discarded —
/// other sessions are unaffected.
pub struct CortexSession<'c> {
    cx: &'c WarpCortex,
    permit: SessionPermit,
    ticket: AgentTicket,
    /// In-flight chunked admission (`None` once the prompt is covered,
    /// always `None` on the monolithic path): the remaining teacher-forced
    /// lanes plus the admission-time block reservation, held until the
    /// prompt's rows are actually rented.  Makes the session a
    /// prefill→decode state machine — the first [`CortexSession::next_token`]
    /// completes coverage before sampling.
    prefill: Option<(ChunkedPrefill, BlockReservation<'c>)>,
    router: Router,
    sampler: Sampler,
    prompt: String,
    /// Truncated prompt token ids (the one admission-time encode): the
    /// prefix-registry chain keys a durable checkpoint stores instead of
    /// the shared blocks' bytes.
    prompt_ids: Vec<i32>,
    /// Durable identity across hibernate/resume cycles (the first permit's
    /// id; later permits differ but the store key does not).
    durable_id: u64,
    logits: Vec<f32>,
    hidden: Vec<f32>,
    /// Triggers seen but not yet routed (prompt triggers before step 1).
    pending: Vec<Trigger>,
    text: String,
    events: Vec<Event>,
    pos: i32,
    generated: usize,
    max_tokens: usize,
    /// Side tasks submitted by this session whose outcomes have not yet
    /// been merged.
    outstanding: usize,
    started: Instant,
    done: bool,
}

impl<'c> CortexSession<'c> {
    /// The scheduler-issued session id (what this session's
    /// [`SideTask::session`] tags carry).
    pub fn id(&self) -> u64 {
        self.permit.id()
    }

    /// Visible text generated so far.
    pub fn text(&self) -> &str {
        &self.text
    }

    pub fn tokens_generated(&self) -> usize {
        self.generated
    }

    /// Park this session's private context blocks to the pool's cold host
    /// slab (capacity: `CortexConfig::kv_pool.host_slab_blocks`): a
    /// client that has gone quiet stops costing device bytes while its
    /// admission slot and cache stay alive.  Registry-shared prefix
    /// blocks are untouched — they demote through the pool's own
    /// offload-under-pressure path.  Returns the blocks parked.
    pub fn park_to_host(&mut self) -> Result<usize> {
        // Checkpoint-on-park policy: a quiescent session's durable record
        // lands before its blocks leave the hot tier, so a crash (or a
        // later preempt-to-disk) can't strand it.
        if self.cx.cfg.checkpoint_on_park && self.cx.store.is_some() {
            self.checkpoint()?;
        }
        self.ticket.kv.park_to_host()
    }

    /// The session's durable store identity — stable across
    /// hibernate/resume cycles (unlike [`CortexSession::id`], which is the
    /// current scheduler permit).  This is the id `POST
    /// /sessions/{id}/resume` takes.
    pub fn durable_id(&self) -> u64 {
        self.durable_id
    }

    /// Whether the serve layer should hibernate this session (checkpoint
    /// it and hand the ticket to the store as a preempt-to-disk
    /// candidate) when its client disconnects mid-stream, instead of
    /// dropping it outright.  True only with a configured store and the
    /// `CortexConfig::checkpoint_on_disconnect` policy on.
    pub fn hibernate_on_disconnect(&self) -> bool {
        self.cx.cfg.checkpoint_on_disconnect && self.cx.store.is_some()
    }

    /// Write this session's durable checkpoint record: identity, sampler
    /// RNG + repetition window, last logits/hidden, the block-table chain
    /// split into registry-shared prefix (stored as hash-chain keys, not
    /// bytes) and private tail rows, and the synapse snapshot version.
    /// After a crash, [`WarpCortex::resume_session`] rebuilds the session
    /// from this record with bit-identical next-token logits.
    pub fn checkpoint(&mut self) -> Result<()> {
        let Some(store) = &self.cx.store else {
            bail!("checkpointing requires CortexConfig::store_path");
        };
        // A mid-chunked-prefill session finishes coverage first: the
        // checkpoint captures a commit point, not a half-fed prompt.
        self.ensure_prefilled()?;
        let bt = self.cx.pool.block_tokens();
        let len = self.ticket.kv.len();
        // Tier tag recorded before page-in; `host_slice` reads require the
        // rows resident, so an offloaded session pages back for the copy
        // (hibernate re-parks right after).
        let offloaded = self.ticket.kv.offloaded_blocks();
        if offloaded > 0 {
            self.ticket.kv.resume_from_host()?;
        }
        // Only *whole* leading shared blocks resume by hash chain; a
        // clamp below len never happens in practice (registry blocks are
        // full), but the floor keeps the geometry sound if it ever did.
        let mut shared_rows = self.ticket.kv.leading_shared_blocks() * bt;
        if shared_rows > len {
            shared_rows = (len / bt) * bt;
        }
        let shared_rows = shared_rows.min(self.prompt_ids.len() / bt * bt);
        let n_layers = self.cx.pool.n_layers();
        let row = self.cx.pool.row();
        let mut k_tail = Vec::with_capacity(n_layers * (len - shared_rows) * row);
        let mut v_tail = Vec::with_capacity(k_tail.capacity());
        for layer in 0..n_layers {
            k_tail.extend(self.ticket.kv.k_slice(layer, shared_rows, len));
            v_tail.extend(self.ticket.kv.v_slice(layer, shared_rows, len));
        }
        let (rng_state, recent) = self.sampler.save_state();
        let cp = SessionCheckpoint {
            id: self.durable_id,
            rng_state,
            synapse_version: self.cx.synapse.version(),
            generated: self.generated as u64,
            max_tokens: self.max_tokens as u64,
            pos: self.pos as i64,
            shared_rows: shared_rows as u32,
            total_rows: len as u32,
            offloaded_blocks: offloaded as u32,
            prompt: self.prompt.clone(),
            text: self.text.clone(),
            prompt_ids: self.prompt_ids.clone(),
            recent,
            logits: self.logits.clone(),
            hidden: self.hidden.clone(),
            k_tail,
            v_tail,
        };
        store.checkpoint(&cp)?;
        Ok(())
    }

    /// Hibernate: checkpoint the durable record, park the context to the
    /// cold host slab, and hand the ticket to the store as a
    /// preempt-to-disk candidate.  Consumes the session — the permit drops
    /// here, freeing the admission slot for a parked arrival; in-flight
    /// side tasks are discarded like any other session drop.  Returns the
    /// durable id [`WarpCortex::resume_session`] takes.
    pub fn hibernate(mut self) -> Result<u64> {
        if self.cx.store.is_none() {
            bail!("hibernation requires CortexConfig::store_path");
        }
        self.ensure_prefilled()?;
        self.checkpoint()?;
        self.ticket.kv.park_to_host()?;
        let id = self.durable_id;
        // No `Drop` impl on CortexSession, so destructuring moves the
        // ticket out; every other field (permit included) drops here.
        let CortexSession { cx, ticket, .. } = self;
        if let Some(store) = &cx.store {
            store.park_resident(id, Box::new(ticket));
        }
        Ok(id)
    }

    /// Page this session's parked blocks back to the hot tier — the
    /// resume half of the park/resume round trip, bit-identical by the
    /// offload tier's contract (tests in `model/kv.rs` prove it).  The
    /// next decode step's cache write would also page in transparently;
    /// the explicit call front-loads the transfer so the resumed stream's
    /// first token doesn't pay it.  Returns the blocks paged in.
    pub fn resume_from_host(&mut self) -> Result<usize> {
        self.ticket.kv.resume_from_host()
    }

    /// Complete a chunked admission: teacher-force the remaining prefill
    /// lanes through the scheduler (budgeted per tick, fused with the
    /// other sessions' decode steps) and seed the sampler state from the
    /// final lane — the first-sample logits.  Block-boundary probes along
    /// the way adopt any identical prefix a concurrent session has
    /// registered mid-prefill.  No-op once the prompt is covered.
    fn ensure_prefilled(&mut self) -> Result<()> {
        let Some((mut cp, rsv)) = self.prefill.take() else {
            return Ok(());
        };
        let mut last = None;
        while let Some((tok, pos)) = cp.next_lane(&mut self.ticket.kv) {
            match self.cx.step.prefill_step(tok, pos, &mut self.ticket.kv) {
                Ok(out) => last = Some(out),
                Err(e) => {
                    self.done = true; // poisoned: no logits to sample from
                    return Err(e);
                }
            }
            cp.advance(&mut self.ticket.kv);
        }
        let out = last.expect("chunked coverage always leaves the final prompt token live");
        self.logits = out.logits;
        self.hidden = out.hidden;
        self.pos = self.ticket.kv.len() as i32;
        drop(rsv); // the prompt's rows are rented now
        Ok(())
    }

    /// Advance one token.  Returns the visible text delta (possibly empty
    /// — not every token decodes to a printable byte), or `None` once the
    /// budget, the cache or an EOS ended generation.
    pub fn next_token(&mut self) -> Result<Option<String>> {
        self.ensure_prefilled()?;
        if self.done || self.generated >= self.max_tokens || self.ticket.kv.remaining() == 0 {
            self.done = true;
            return Ok(None);
        }
        // ── decode one token through the step scheduler ──
        // The step runs at River priority inside the next fused tick
        // (a leading lane of the batch op shared with the other sessions,
        // or its own op ahead of the side batch once the context outgrows
        // a side lane) — never queued behind side work.
        let t0 = Instant::now();
        let id = self.sampler.sample(&self.logits);
        if id == EOS_ID {
            self.done = true;
            return Ok(None);
        }
        let out = self.cx.step.main_step(id, self.pos, &mut self.ticket.kv)?;
        self.cx.step_latency.record(t0.elapsed());
        self.cx.main_throughput.tick();
        self.logits = out.logits;
        self.hidden = out.hidden;
        self.pos += 1;
        self.generated += 1;

        let mut delta = String::new();
        let mut new_triggers: Vec<Trigger> = std::mem::take(&mut self.pending);
        if let Some(b) = self.cx.tokenizer.decode_one(id) {
            delta.push(b as char);
            self.text.push(b as char);
            if let Some(tr) = self.router.feed_byte(b) {
                new_triggers.push(tr);
            }
        }

        // ── synapse refresh (Background lane) ──
        let due = self.generated % self.cx.cfg.synapse_refresh_every == 0;
        let need = !new_triggers.is_empty() && self.cx.synapse.read().is_none();
        if (due || need) && self.ticket.kv.len() >= self.cx.engine.caps().synapse_k {
            let s = self
                .cx
                .engine
                .synapse_extract(&self.hidden, &self.ticket.kv, Lane::Background)?;
            let source_len = s.source_len;
            let version = self.cx.synapse.push(s);
            self.events.push(Event::SynapsePushed {
                version,
                source_len,
                at_token: self.generated,
            });
        }

        // ── route triggers to side agents (tagged with this session) ──
        for tr in new_triggers {
            if self.cx.synapse.read().is_none() {
                self.events.push(Event::Dropped {
                    payload: tr.payload,
                    at_token: self.generated,
                });
                continue;
            }
            let task = SideTask {
                id: self.cx.next_task_id(),
                session: self.permit.id(),
                role: tr.role,
                payload: tr.payload.clone(),
                main_pos: self.pos,
                spawned_at: Instant::now(),
            };
            let task_id = task.id;
            if self.cx.step.submit(task) {
                self.outstanding += 1;
                self.events.push(Event::Spawned {
                    task_id,
                    tag: tr.tag,
                    payload: tr.payload,
                    at_token: self.generated,
                });
            } else {
                self.events.push(Event::Dropped {
                    payload: tr.payload,
                    at_token: self.generated,
                });
            }
        }

        // ── merge this session's finished side agents ──
        for outcome in self.cx.step.poll_session_results(self.permit.id()) {
            self.outstanding = self.outstanding.saturating_sub(1);
            self.cx.merge_outcome(
                outcome,
                &self.hidden,
                &mut self.ticket,
                self.pos,
                self.generated,
                &mut self.events,
            )?;
        }
        Ok(Some(delta))
    }

    /// Finalize: drain this session's in-flight side agents (bounded grace
    /// window, so every spawned task reaches a terminal event) and build
    /// the episode report.  Consumes the session — the permit and ticket
    /// drop here, freeing the slot and the cache blocks.
    pub fn finish(mut self) -> Result<EpisodeReport> {
        let deadline = Instant::now() + Duration::from_secs(2);
        while self.outstanding > 0 && Instant::now() < deadline {
            if let Some(outcome) = self
                .cx
                .step
                .wait_session_result(self.permit.id(), Duration::from_millis(100))
            {
                self.outstanding = self.outstanding.saturating_sub(1);
                self.cx.merge_outcome(
                    outcome,
                    &self.hidden,
                    &mut self.ticket,
                    self.pos,
                    self.generated,
                    &mut self.events,
                )?;
            }
        }
        for outcome in self.cx.step.poll_session_results(self.permit.id()) {
            self.outstanding = self.outstanding.saturating_sub(1);
            self.cx.merge_outcome(
                outcome,
                &self.hidden,
                &mut self.ticket,
                self.pos,
                self.generated,
                &mut self.events,
            )?;
        }
        let elapsed = self.started.elapsed();
        Ok(EpisodeReport {
            prompt: self.prompt,
            text: self.text,
            tokens_generated: self.generated,
            events: self.events,
            elapsed,
            main_tokens_per_sec: self.generated as f64 / elapsed.as_secs_f64().max(1e-9),
            step_latency_p50_ns: self.cx.step_latency.percentile_ns(50.0),
            step_latency_p95_ns: self.cx.step_latency.percentile_ns(95.0),
            gate: self.cx.gate.stats(),
            inject: self.cx.injector.stats(),
            synapse: self.cx.synapse.stats(),
            scheduler: self.cx.step.stats(),
            memory: self.cx.tracker.snapshot(),
            pool: self.cx.pool.stats(),
        })
    }
}
