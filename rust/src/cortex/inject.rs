//! Referential Injection (paper §3.6): merge a side agent's thought into the
//! Main Agent's KV cache without touching the visible text stream.
//!
//! Mechanism, exactly as the paper describes it one level down the stack:
//! the thought tokens get a forward pass at *virtual RoPE positions*
//! (`inject_encode` artifact), and the resulting K/V rows are appended
//! beyond the Main Agent's current rows.  Subsequent decode steps attend
//! over them (`cache_len` grows) while the text position bookkeeping is
//! unchanged — the agent "remembers" the thought mid-sentence.
//!
//! The injector also enforces *headroom*: injections are refused when they
//! would starve the main cache of generation capacity.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::model::{Engine, KvCache};
use crate::runtime::Lane;

/// Report of one successful injection.
#[derive(Debug, Clone)]
pub struct InjectReport {
    /// Rows appended to the main cache.
    pub rows: usize,
    /// Virtual RoPE base position the thought was encoded at.
    pub pos_base: i32,
    /// Cache length before / after.
    pub len_before: usize,
    pub len_after: usize,
    /// Bytes the injected rows occupy.
    pub bytes: u64,
}

/// Injection statistics.
#[derive(Debug, Clone, Default)]
pub struct InjectStats {
    pub injected: u64,
    pub refused_headroom: u64,
    pub rows_total: u64,
}

/// Injection policy + mechanism.
#[derive(Debug)]
pub struct Injector {
    /// Always keep at least this many free rows for main-agent generation.
    pub reserve_rows: usize,
    injected: AtomicU64,
    refused: AtomicU64,
    rows_total: AtomicU64,
}

impl Injector {
    pub fn new(reserve_rows: usize) -> Injector {
        Injector {
            reserve_rows,
            injected: AtomicU64::new(0),
            refused: AtomicU64::new(0),
            rows_total: AtomicU64::new(0),
        }
    }

    /// Would an injection of `rows` rows fit right now?
    pub fn has_headroom(&self, kv: &KvCache, rows: usize) -> bool {
        kv.remaining() >= rows + self.reserve_rows
    }

    /// Inject `thought_tokens` into the main cache at virtual positions
    /// starting from the agent's current text position `main_pos`.
    ///
    /// The thought is truncated to the artifact's `inject_len`.  Runs the
    /// reference forward pass on `lane` (typically `Stream`: injection work
    /// must never preempt River decode ops).
    pub fn inject(
        &self,
        engine: &Engine,
        kv: &mut KvCache,
        thought_tokens: &[i32],
        main_pos: i32,
        lane: Lane,
    ) -> Result<InjectReport> {
        if thought_tokens.is_empty() {
            bail!("inject: empty thought");
        }
        let rows = thought_tokens.len().min(engine.caps().inject_len);
        if !self.has_headroom(kv, rows) {
            self.refused.fetch_add(1, Ordering::Relaxed);
            bail!(
                "inject: no headroom ({} free, need {} + {} reserve)",
                kv.remaining(),
                rows,
                self.reserve_rows
            );
        }
        let len_before = kv.len();
        let enc = engine.inject_encode(&thought_tokens[..rows], main_pos, lane)?;
        let (k_rows, v_rows) = engine.slice_inject_rows(&enc, enc.len);
        kv.append_rows(enc.len, &k_rows, &v_rows)?;
        self.injected.fetch_add(1, Ordering::Relaxed);
        self.rows_total.fetch_add(enc.len as u64, Ordering::Relaxed);
        let row_bytes = engine.config().kv_row_bytes(4);
        Ok(InjectReport {
            rows: enc.len,
            pos_base: main_pos,
            len_before,
            len_after: kv.len(),
            bytes: row_bytes * enc.len as u64,
        })
    }

    pub fn stats(&self) -> InjectStats {
        InjectStats {
            injected: self.injected.load(Ordering::Relaxed),
            refused_headroom: self.refused.load(Ordering::Relaxed),
            rows_total: self.rows_total.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    #[test]
    fn headroom_math() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 32);
        let row = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        for _ in 0..20 {
            kv.append_row(&vec![0.0; row], &vec![0.0; row]).unwrap();
        }
        let inj = Injector::new(8);
        assert!(inj.has_headroom(&kv, 4)); // 12 free >= 4 + 8
        assert!(!inj.has_headroom(&kv, 5)); // 12 free < 5 + 8
    }

    // The end-to-end inject path (with the real engine) is covered by
    // rust/tests/integration_cortex.rs.
}
