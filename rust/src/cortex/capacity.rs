//! Capacity planning: where does agent scaling actually stop?
//!
//! The paper's abstract claims "theoretical capacity exceeding 1,000 agents
//! before compute latency becomes the bottleneck" and the title says
//! "million-agent".  This module makes that claim precise and testable: a
//! two-resource model (memory bytes, device-seconds) that, given measured
//! per-op costs, finds the binding constraint at every population size.
//!
//! Model: N agents = 1 main (continuous decoding at `main_rate` tok/s) +
//! (N−1) side agents, each consuming `side_duty` device-tokens per main
//! token (side agents are bursty; duty is the time-averaged rate).  One
//! device executes ops serially (the River preempts at op granularity, so
//! main latency stays ~1 op; what saturates is total utilization):
//!
//!   util(N) = main_rate · t_main + (N−1) · side_duty · main_rate · t_side/B
//!
//! Memory: the Table-1/Table-2 arithmetic from [`super::memory`].

use super::memory::MemoryModel;

/// Per-op device costs (seconds), measured or projected.
#[derive(Debug, Clone)]
pub struct ComputeCosts {
    /// One main-agent decode op.
    pub t_main_decode: f64,
    /// One *batched* side decode op (B tokens per op).
    pub t_side_batch: f64,
    pub batch_width: usize,
}

/// The full capacity model.
#[derive(Debug, Clone)]
pub struct CapacityModel {
    pub mem: MemoryModel,
    pub compute: ComputeCosts,
    /// Main agent's sustained generation rate (tok/s).
    pub main_rate: f64,
    /// Average side-agent tokens generated per main-agent token.
    pub side_duty: f64,
}

/// Why scaling stops at a given population.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Feasible,
    Memory,
    Compute,
}

/// One point of the scaling curve.
#[derive(Debug, Clone)]
pub struct CapacityPoint {
    pub agents: u64,
    pub mem_bytes: u64,
    pub utilization: f64,
    pub bottleneck: Bottleneck,
}

impl CapacityModel {
    /// Device utilization in [0, ∞): >1 means the op stream no longer fits.
    pub fn utilization(&self, agents: u64) -> f64 {
        let side = agents.saturating_sub(1) as f64;
        let side_tokens_per_sec = side * self.side_duty * self.main_rate;
        self.main_rate * self.compute.t_main_decode
            + side_tokens_per_sec * self.compute.t_side_batch
                / self.compute.batch_width as f64
    }

    pub fn evaluate(&self, agents: u64) -> CapacityPoint {
        let mem_bytes = self.mem.warp_total_bytes(agents);
        let utilization = self.utilization(agents);
        let over_mem = mem_bytes > self.mem.vram_total - self.mem.vram_reserved;
        let bottleneck = match (over_mem, utilization > 1.0) {
            (false, false) => Bottleneck::Feasible,
            // report the constraint that binds FIRST as N grows
            (true, false) => Bottleneck::Memory,
            (false, true) => Bottleneck::Compute,
            (true, true) => {
                if self.max_agents_memory() < self.max_agents_compute() {
                    Bottleneck::Memory
                } else {
                    Bottleneck::Compute
                }
            }
        };
        CapacityPoint {
            agents,
            mem_bytes,
            utilization,
            bottleneck,
        }
    }

    /// Largest N that fits memory.
    pub fn max_agents_memory(&self) -> u64 {
        self.mem.max_agents_warp()
    }

    /// Largest N with utilization <= 1.
    pub fn max_agents_compute(&self) -> u64 {
        let fixed = self.main_rate * self.compute.t_main_decode;
        if fixed >= 1.0 {
            return 0;
        }
        let per_side = self.side_duty * self.main_rate * self.compute.t_side_batch
            / self.compute.batch_width as f64;
        if per_side <= 0.0 {
            return u64::MAX;
        }
        1 + ((1.0 - fixed) / per_side) as u64
    }

    /// The population where scaling stops, and why.
    pub fn limit(&self) -> (u64, Bottleneck) {
        let m = self.max_agents_memory();
        let c = self.max_agents_compute();
        if c < m {
            (c, Bottleneck::Compute)
        } else {
            (m, Bottleneck::Memory)
        }
    }

    /// Log-spaced scaling curve up to `max_n`.
    pub fn curve(&self, max_n: u64) -> Vec<CapacityPoint> {
        let mut points = Vec::new();
        let mut n = 1u64;
        while n <= max_n {
            points.push(self.evaluate(n));
            n = if n < 10 { n * 2 } else { n * 10 / 3 };
        }
        points
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cortex::memory::{MemoryModel, GIB, MIB};

    fn model(t_side_batch: f64) -> CapacityModel {
        CapacityModel {
            mem: MemoryModel {
                config_name: "test".into(),
                kv_row_bytes: 12288,
                weight_bytes: GIB,
                full_ctx: 32768,
                synapse_k: 64,
                side_gen: 32,
                per_agent_overhead: 12 * MIB,
                vram_total: 24 * GIB,
                vram_reserved: GIB,
            },
            compute: ComputeCosts {
                t_main_decode: 2e-3,
                t_side_batch,
                batch_width: 4,
            },
            main_rate: 30.0,
            side_duty: 0.25,
        }
    }

    #[test]
    fn compute_limit_math() {
        let m = model(4e-3);
        // fixed = 30*2e-3 = 0.06; per_side = 0.25*30*1e-3 = 7.5e-3
        // max = 1 + (0.94/0.0075) = 1 + 125
        assert_eq!(m.max_agents_compute(), 126);
        assert!(m.utilization(126) <= 1.0 + 1e-9);
        assert!(m.utilization(130) > 1.0);
    }

    #[test]
    fn limit_reports_binding_constraint() {
        // slow device → compute binds before memory
        let slow = model(4e-3);
        let (n, why) = slow.limit();
        assert_eq!(why, Bottleneck::Compute);
        assert!(n < slow.max_agents_memory());

        // very fast device → memory binds
        let fast = model(1e-7);
        let (n, why) = fast.limit();
        assert_eq!(why, Bottleneck::Memory);
        assert_eq!(n, fast.max_agents_memory());
        assert!(n > 1000, "paper's 1000+ agent claim should hold: {n}");
    }

    #[test]
    fn curve_is_monotone_and_classified() {
        let m = model(4e-3);
        let curve = m.curve(100_000);
        for w in curve.windows(2) {
            assert!(w[1].mem_bytes >= w[0].mem_bytes);
            assert!(w[1].utilization >= w[0].utilization);
        }
        assert_eq!(curve.first().unwrap().bottleneck, Bottleneck::Feasible);
        assert_ne!(curve.last().unwrap().bottleneck, Bottleneck::Feasible);
    }

    #[test]
    fn million_agents_is_memory_bound_on_one_card() {
        // The title's "million-agent" scaling: even with zero compute cost,
        // one 24 GB card cannot hold 1M × (synapse + overhead) — the model
        // quantifies exactly how far the memory axis carries.
        let free = model(0.0);
        assert_eq!(free.max_agents_compute(), u64::MAX);
        let at_million = free.evaluate(1_000_000);
        assert_eq!(at_million.bottleneck, Bottleneck::Memory);
        // ... unless the per-agent footprint drops to the synapse-only row
        // the paper's Table 1 quotes (≈0.8 MB): then ~28k agents/card, and
        // a million agents is a ~36-card (not data-center) problem.
        let mut slim = free.clone();
        slim.mem.per_agent_overhead = 0;
        slim.mem.side_gen = 0;
        let per_card = slim.max_agents_memory();
        assert!(per_card > 20_000, "{per_card}");
        assert!((1_000_000 / per_card) < 50);
    }
}
