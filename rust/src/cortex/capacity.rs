//! Capacity planning: where does agent scaling actually stop?
//!
//! The paper's abstract claims "theoretical capacity exceeding 1,000 agents
//! before compute latency becomes the bottleneck" and the title says
//! "million-agent".  This module makes that claim precise and testable: a
//! two-resource model (memory bytes, device-seconds) that, given measured
//! per-op costs, finds the binding constraint at every population size.
//!
//! Model: N agents = 1 main (continuous decoding at `main_rate` tok/s) +
//! (N−1) side agents, each consuming `side_duty` device-tokens per main
//! token (side agents are bursty; duty is the time-averaged rate).  One
//! device executes ops serially (the River preempts at op granularity, so
//! main latency stays ~1 op; what saturates is total utilization):
//!
//!   util(N) = main_rate · t_main + (N−1) · side_duty · main_rate · t_side/B
//!
//! Since the PR-4 step scheduler the serving path no longer issues that
//! serial stream: main and side steps fuse into shared batch ticks, so the
//! fused model ([`CapacityModel::utilization_fused`]) charges
//! `max(1, tokens-per-main-token / B)` batch ops per main token instead of
//! `1 main op + side ops` — the `t_main` term disappears into lane 0 of
//! the batch op and the compute ceiling moves out accordingly.
//!
//! The multi-session scheduler adds a third axis: S concurrent serving
//! *sessions*, each its own episode population, sharing the fused tick
//! loop.  [`CapacityModel::utilization_sessions`] charges
//! `max(1, S·(1+(n−1)·side_duty) / B)` batch ops per main token —
//! sequential-episode serving would pay S single-session op streams —
//! and [`CapacityModel::max_sessions_compute`] inverts it into the
//! serving layer's `max_sessions` planning figure (Table-3-style curves
//! via [`CapacityModel::sessions_curve`]).
//!
//! All entry points validate the model first and return a typed
//! [`CapacityError`] for degenerate inputs (`batch_width == 0`,
//! non-positive `main_rate`, negative `side_duty`, non-finite costs) —
//! the pre-PR-4 arithmetic silently produced `inf`/`NaN` utilization
//! curves instead.
//!
//! Memory: the Table-1/Table-2 arithmetic from [`super::memory`].  Since
//! the tiered KV store the memory axis comes in two flavours: the fp32
//! hot-tier charge ([`CapacityModel::evaluate`]) and the warm int8 tier
//! ([`CapacityModel::evaluate_q8`]), where parked side-agent context is
//! block-granularly quantized (int8 values + one fp32 scale per
//! (layer, K/V) row — ~4× rows per GB).  Compute is tier-blind: gathers
//! dequantize transparently, so only the memory ceiling moves.

use super::memory::MemoryModel;

/// Per-op device costs (seconds), measured or projected.
#[derive(Debug, Clone)]
pub struct ComputeCosts {
    /// One main-agent decode op.
    pub t_main_decode: f64,
    /// One *batched* side decode op (B tokens per op).
    pub t_side_batch: f64,
    pub batch_width: usize,
}

/// Why a capacity model is unusable (degenerate inputs that would
/// otherwise propagate as `inf`/`NaN` through every curve).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CapacityError {
    /// `batch_width == 0`: the per-op token count divides by it.
    ZeroBatchWidth,
    /// `main_rate <= 0` (or non-finite): the model is parameterised per
    /// main token, so a non-positive rate has no meaning.
    NonPositiveMainRate(f64),
    /// `side_duty < 0` (or NaN): side agents cannot consume negative
    /// device-tokens.
    NegativeSideDuty(f64),
    /// A per-op cost is negative or non-finite.
    NonFiniteCost {
        which: &'static str,
        value: f64,
    },
    /// `prefill_budget == 0`: zero lanes per tick would never finish a
    /// prompt (the scheduler clamps to 1; the model rejects outright).
    ZeroPrefillBudget,
}

impl std::fmt::Display for CapacityError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CapacityError::ZeroBatchWidth => write!(f, "capacity model: batch_width is 0"),
            CapacityError::NonPositiveMainRate(r) => {
                write!(f, "capacity model: main_rate {r} is not a positive finite rate")
            }
            CapacityError::NegativeSideDuty(d) => {
                write!(f, "capacity model: side_duty {d} is negative (or NaN)")
            }
            CapacityError::NonFiniteCost { which, value } => {
                write!(f, "capacity model: {which} = {value} is not a finite non-negative cost")
            }
            CapacityError::ZeroPrefillBudget => {
                write!(f, "capacity model: prefill_budget is 0 (a prompt would never finish)")
            }
        }
    }
}

impl std::error::Error for CapacityError {}

/// The full capacity model.
#[derive(Debug, Clone)]
pub struct CapacityModel {
    pub mem: MemoryModel,
    pub compute: ComputeCosts,
    /// Main agent's sustained generation rate (tok/s).
    pub main_rate: f64,
    /// Average side-agent tokens generated per main-agent token.
    pub side_duty: f64,
}

/// Why scaling stops at a given population.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    Feasible,
    Memory,
    Compute,
}

/// One point of the scaling curve.
#[derive(Debug, Clone)]
pub struct CapacityPoint {
    pub agents: u64,
    pub mem_bytes: u64,
    pub utilization: f64,
    pub bottleneck: Bottleneck,
}

/// One point of the TTFT-vs-budget trade-off curve
/// ([`CapacityModel::prefill_curve`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PrefillPoint {
    /// Teacher-forced lanes per fused tick ([`crate::cortex::step::StepConfig::prefill_budget`]).
    pub prefill_budget: u64,
    /// Fused ticks until the admission's first sampled token.
    pub ttft_ticks: u64,
    /// The same figure in seconds (one batch op per tick).
    pub ttft_seconds: f64,
    /// Worst extra inter-token gap (fused ticks) concurrent decode
    /// streams see while the prompt admits — constant under chunking.
    pub tpot_stall_ticks: f64,
}

impl CapacityModel {
    /// Reject degenerate parameters before any arithmetic: every public
    /// entry point calls this, so a `batch_width` of 0 or a negative duty
    /// surfaces as a typed [`CapacityError`] instead of an `inf`/`NaN`
    /// utilization curve.
    pub fn validate(&self) -> Result<(), CapacityError> {
        if self.compute.batch_width == 0 {
            return Err(CapacityError::ZeroBatchWidth);
        }
        if !(self.main_rate.is_finite() && self.main_rate > 0.0) {
            return Err(CapacityError::NonPositiveMainRate(self.main_rate));
        }
        if !(self.side_duty >= 0.0 && self.side_duty.is_finite()) {
            return Err(CapacityError::NegativeSideDuty(self.side_duty));
        }
        for (which, value) in [
            ("t_main_decode", self.compute.t_main_decode),
            ("t_side_batch", self.compute.t_side_batch),
        ] {
            if !(value.is_finite() && value >= 0.0) {
                return Err(CapacityError::NonFiniteCost { which, value });
            }
        }
        Ok(())
    }

    /// Device utilization in [0, ∞) under the legacy serial op stream
    /// (one main op per token + linger-batched side ops): >1 means the op
    /// stream no longer fits.
    pub fn utilization(&self, agents: u64) -> Result<f64, CapacityError> {
        self.validate()?;
        let side = agents.saturating_sub(1) as f64;
        let side_tokens_per_sec = side * self.side_duty * self.main_rate;
        Ok(self.main_rate * self.compute.t_main_decode
            + side_tokens_per_sec * self.compute.t_side_batch
                / self.compute.batch_width as f64)
    }

    /// Device utilization under the step scheduler's fused ticks: per main
    /// token the population produces `1 + (N−1)·side_duty` tokens, carried
    /// by `max(1, tokens / B)` batch ops — there is no separate main op,
    /// so the `t_main` term disappears into lane 0 of the batch program.
    pub fn utilization_fused(&self, agents: u64) -> Result<f64, CapacityError> {
        self.validate()?;
        let b = self.compute.batch_width as f64;
        let tokens_per_main_token = 1.0 + agents.saturating_sub(1) as f64 * self.side_duty;
        let ops_per_main_token = (tokens_per_main_token / b).max(1.0);
        Ok(self.main_rate * ops_per_main_token * self.compute.t_side_batch)
    }

    pub fn evaluate(&self, agents: u64) -> Result<CapacityPoint, CapacityError> {
        let mem_bytes = self.mem.warp_total_bytes(agents);
        let utilization = self.utilization(agents)?;
        let over_mem = mem_bytes > self.mem.vram_total - self.mem.vram_reserved;
        let bottleneck = match (over_mem, utilization > 1.0) {
            (false, false) => Bottleneck::Feasible,
            // report the constraint that binds FIRST as N grows
            (true, false) => Bottleneck::Memory,
            (false, true) => Bottleneck::Compute,
            (true, true) => {
                if self.max_agents_memory() < self.max_agents_compute()? {
                    Bottleneck::Memory
                } else {
                    Bottleneck::Compute
                }
            }
        };
        Ok(CapacityPoint {
            agents,
            mem_bytes,
            utilization,
            bottleneck,
        })
    }

    /// Largest N that fits memory.
    pub fn max_agents_memory(&self) -> u64 {
        self.mem.max_agents_warp()
    }

    /// Largest N with serial-stream utilization <= 1.
    pub fn max_agents_compute(&self) -> Result<u64, CapacityError> {
        self.validate()?;
        let fixed = self.main_rate * self.compute.t_main_decode;
        if fixed >= 1.0 {
            return Ok(0);
        }
        let per_side = self.side_duty * self.main_rate * self.compute.t_side_batch
            / self.compute.batch_width as f64;
        if per_side <= 0.0 {
            return Ok(u64::MAX);
        }
        Ok(1 + ((1.0 - fixed) / per_side) as u64)
    }

    /// Largest N with *fused-tick* utilization <= 1 (the step-scheduler
    /// ceiling).  Always ≥ the serial figure when `t_side_batch` is the
    /// binding cost, because the dedicated per-token main op is gone.
    pub fn max_agents_compute_fused(&self) -> Result<u64, CapacityError> {
        self.validate()?;
        let b = self.compute.batch_width as f64;
        // Floor cost: even a lone main pays one batch op per token.
        let t = self.main_rate * self.compute.t_side_batch;
        if t >= 1.0 {
            return Ok(0);
        }
        if self.side_duty <= 0.0 {
            return Ok(u64::MAX);
        }
        // util = main_rate * t_side_batch * tokens / B <= 1 once tokens > B
        //   ⇒ tokens <= B / (main_rate * t_side_batch)   (≥ B since t < 1)
        let max_tokens = (b / t).max(b);
        Ok(1 + ((max_tokens - 1.0) / self.side_duty) as u64)
    }

    // ── Multi-session model (Table-3-style curves) ─────────────────────
    //
    // Since the multi-session scheduler, S independent serving sessions —
    // each a full episode population of 1 main + (n−1) side agents —
    // share the fused tick loop: their S main steps ride the leading
    // lanes of the same batch op.  Per main-token interval (1/main_rate
    // seconds, sessions assumed rate-matched) the system therefore
    // produces `S · (1 + (n−1)·side_duty)` tokens, carried by
    // `max(1, tokens/B)` batch ops — sequential-episode serving would pay
    // `S` times the single-session op stream instead.

    /// Fused-tick device utilization with `sessions` concurrent main
    /// streams, each running `agents_per_session` agents (1 main +
    /// n−1 sides).  `utilization_sessions(1, n) == utilization_fused(n)`.
    pub fn utilization_sessions(
        &self,
        sessions: u64,
        agents_per_session: u64,
    ) -> Result<f64, CapacityError> {
        self.validate()?;
        if sessions == 0 {
            return Ok(0.0);
        }
        let b = self.compute.batch_width as f64;
        let per_session =
            1.0 + agents_per_session.saturating_sub(1) as f64 * self.side_duty;
        let tokens_per_main_token = sessions as f64 * per_session;
        let ops_per_main_token = (tokens_per_main_token / b).max(1.0);
        Ok(self.main_rate * ops_per_main_token * self.compute.t_side_batch)
    }

    /// Largest concurrent-session count with fused utilization <= 1 at a
    /// fixed per-session population (the serving-layer `max_sessions`
    /// planning figure).
    pub fn max_sessions_compute(&self, agents_per_session: u64) -> Result<u64, CapacityError> {
        self.validate()?;
        let b = self.compute.batch_width as f64;
        let t = self.main_rate * self.compute.t_side_batch;
        if t >= 1.0 {
            // Even one batch op per main token oversubscribes the device.
            return Ok(0);
        }
        let per_session =
            1.0 + agents_per_session.saturating_sub(1) as f64 * self.side_duty;
        // util <= 1  ⇔  tokens <= B / t  (and ops floor at 1 keeps any
        // S with tokens <= B feasible since t < 1); per_session >= 1.
        let max_tokens = (b / t).max(b);
        Ok((max_tokens / per_session) as u64)
    }

    /// Log-spaced utilization curve over the session axis at a fixed
    /// per-session population: the Table-3-style view of how far
    /// iteration-level multi-session batching carries before compute
    /// binds.
    pub fn sessions_curve(
        &self,
        max_sessions: u64,
        agents_per_session: u64,
    ) -> Result<Vec<(u64, f64)>, CapacityError> {
        self.validate()?;
        let mut points = Vec::new();
        let mut s = 1u64;
        while s <= max_sessions {
            points.push((s, self.utilization_sessions(s, agents_per_session)?));
            s = if s < 10 { s * 2 } else { s * 10 / 3 };
        }
        Ok(points)
    }

    // ── Chunked-prefill admission model (TTFT vs TPOT) ─────────────────
    //
    // Since the chunked-prefill scheduler, a prompt admitting into a busy
    // system teacher-forces `prefill_budget` lanes per fused tick instead
    // of running one monolithic prefill op.  Two figures fall out, both in
    // fused-tick units so they compose with the utilization model above:
    //
    //  * TTFT — ticks until the first sampled token:
    //    `ceil(uncovered / budget)` where `uncovered` is the prompt minus
    //    any prefix-registry rows adopted for free (begin-time attach or
    //    mid-prefill hits).  Raising the budget buys TTFT linearly.
    //
    //  * TPOT inflation — the worst extra inter-token gap a concurrent
    //    decode stream sees while the prompt admits.  Chunked lanes ride
    //    the SAME fused op and the fair interleave cedes a decode lane at
    //    most every other tick, so the bound is a constant 2 ticks —
    //    independent of prompt length.  A monolithic admission instead
    //    monopolizes the device for the prompt's whole prefill,
    //    ≈ `prompt / B` fused-tick equivalents (B lanes per op).

    /// Fused ticks until a chunked admission's first sample.
    /// `cached_rows` is the prefix-registry coverage adopted for free; the
    /// final prompt token always decodes live, so the result is ≥ 1.
    pub fn ttft_ticks_chunked(
        &self,
        prompt_tokens: u64,
        cached_rows: u64,
        prefill_budget: u64,
    ) -> Result<u64, CapacityError> {
        if prefill_budget == 0 {
            return Err(CapacityError::ZeroPrefillBudget);
        }
        let uncovered = prompt_tokens.saturating_sub(cached_rows).max(1);
        #[allow(clippy::manual_div_ceil)] // u64::div_ceil needs rustc 1.73; MSRV is 1.70
        Ok((uncovered + prefill_budget - 1) / prefill_budget)
    }

    /// [`CapacityModel::ttft_ticks_chunked`] in seconds, charging one
    /// fused batch op per tick.
    pub fn ttft_seconds_chunked(
        &self,
        prompt_tokens: u64,
        cached_rows: u64,
        prefill_budget: u64,
    ) -> Result<f64, CapacityError> {
        self.validate()?;
        let ticks = self.ttft_ticks_chunked(prompt_tokens, cached_rows, prefill_budget)?;
        Ok(ticks as f64 * self.compute.t_side_batch)
    }

    /// Worst-case extra inter-token gap (fused ticks) a decode stream sees
    /// while a prompt admits monolithically: the prefill op monopolizes
    /// the device for ≈ `prompt / B` tick-equivalents.
    pub fn tpot_stall_monolithic_ticks(&self, prompt_tokens: u64) -> Result<f64, CapacityError> {
        self.validate()?;
        Ok(prompt_tokens as f64 / self.compute.batch_width as f64)
    }

    /// The chunked counterpart: a constant bound, independent of prompt
    /// length — a ceded decode lane runs by the next tick and the fair
    /// interleave never cedes on consecutive ticks.
    pub fn tpot_stall_chunked_ticks(&self) -> f64 {
        2.0
    }

    /// TTFT-vs-budget trade-off curve for one admission (budgets
    /// `1..=max_budget`): TTFT falls linearly with the budget while the
    /// decode-stall bound stays constant — the dial the serving layer
    /// turns via `CortexConfig::prefill_budget`.
    pub fn prefill_curve(
        &self,
        prompt_tokens: u64,
        cached_rows: u64,
        max_budget: u64,
    ) -> Result<Vec<PrefillPoint>, CapacityError> {
        self.validate()?;
        (1..=max_budget.max(1))
            .map(|budget| {
                let ttft_ticks = self.ttft_ticks_chunked(prompt_tokens, cached_rows, budget)?;
                Ok(PrefillPoint {
                    prefill_budget: budget,
                    ttft_ticks,
                    ttft_seconds: ttft_ticks as f64 * self.compute.t_side_batch,
                    tpot_stall_ticks: self.tpot_stall_chunked_ticks(),
                })
            })
            .collect()
    }

    // ── Tiered-KV memory axis (warm int8 parked tier) ──────────────────
    //
    // The pool's quantized tier stores parked / registered-prefix blocks
    // as int8 with per-row fp32 scales, so side-agent context — which is
    // parked almost all the time under bursty duty cycles — charges at
    // `kv_row_bytes_q8` instead of `kv_row_bytes`.  These entry points
    // re-run the Table-1/2 arithmetic with that rate: same compute model
    // (dequantize is transparent in the gather), smaller memory term.

    /// Largest N that fits memory with parked side-agent context in the
    /// warm int8 tier — the "quantized" column of Table 1.
    pub fn max_agents_memory_q8(&self) -> u64 {
        self.mem.max_agents_warp_q8()
    }

    /// [`CapacityModel::evaluate`] with side-agent context charged at the
    /// quantized tier's rate.  Utilization is identical (the tier changes
    /// bytes, not ops); only the memory classification moves.
    pub fn evaluate_q8(&self, agents: u64) -> Result<CapacityPoint, CapacityError> {
        let mem_bytes = self.mem.warp_total_bytes_q8(agents);
        let utilization = self.utilization(agents)?;
        let over_mem = mem_bytes > self.mem.vram_total - self.mem.vram_reserved;
        let bottleneck = match (over_mem, utilization > 1.0) {
            (false, false) => Bottleneck::Feasible,
            (true, false) => Bottleneck::Memory,
            (false, true) => Bottleneck::Compute,
            (true, true) => {
                if self.max_agents_memory_q8() < self.max_agents_compute()? {
                    Bottleneck::Memory
                } else {
                    Bottleneck::Compute
                }
            }
        };
        Ok(CapacityPoint {
            agents,
            mem_bytes,
            utilization,
            bottleneck,
        })
    }

    /// The population where scaling stops under the quantized tier, and
    /// why.  With compute held fixed, the tier can only move a Memory
    /// limit outward — a Compute limit stays put.
    pub fn limit_q8(&self) -> Result<(u64, Bottleneck), CapacityError> {
        let m = self.max_agents_memory_q8();
        let c = self.max_agents_compute()?;
        Ok(if c < m {
            (c, Bottleneck::Compute)
        } else {
            (m, Bottleneck::Memory)
        })
    }

    /// Log-spaced scaling curve up to `max_n` with the quantized memory
    /// axis — plotted beside [`CapacityModel::curve`], the pair is the
    /// Table-2 fp32-vs-int8 comparison.
    pub fn curve_q8(&self, max_n: u64) -> Result<Vec<CapacityPoint>, CapacityError> {
        self.validate()?;
        let mut points = Vec::new();
        let mut n = 1u64;
        while n <= max_n {
            points.push(self.evaluate_q8(n)?);
            n = if n < 10 { n * 2 } else { n * 10 / 3 };
        }
        Ok(points)
    }

    /// The population where scaling stops, and why.
    pub fn limit(&self) -> Result<(u64, Bottleneck), CapacityError> {
        let m = self.max_agents_memory();
        let c = self.max_agents_compute()?;
        Ok(if c < m {
            (c, Bottleneck::Compute)
        } else {
            (m, Bottleneck::Memory)
        })
    }

    /// Log-spaced scaling curve up to `max_n`.
    pub fn curve(&self, max_n: u64) -> Result<Vec<CapacityPoint>, CapacityError> {
        self.validate()?;
        let mut points = Vec::new();
        let mut n = 1u64;
        while n <= max_n {
            points.push(self.evaluate(n)?);
            n = if n < 10 { n * 2 } else { n * 10 / 3 };
        }
        Ok(points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cortex::memory::{MemoryModel, GIB, MIB};

    fn model(t_side_batch: f64) -> CapacityModel {
        CapacityModel {
            mem: MemoryModel {
                config_name: "test".into(),
                kv_row_bytes: 12288,
                // int8 values (half of the 2-byte fp16 rows) + per-row scales
                kv_row_bytes_q8: 6336,
                weight_bytes: GIB,
                full_ctx: 32768,
                synapse_k: 64,
                side_gen: 32,
                per_agent_overhead: 12 * MIB,
                vram_total: 24 * GIB,
                vram_reserved: GIB,
            },
            compute: ComputeCosts {
                t_main_decode: 2e-3,
                t_side_batch,
                batch_width: 4,
            },
            main_rate: 30.0,
            side_duty: 0.25,
        }
    }

    #[test]
    fn compute_limit_math() {
        let m = model(4e-3);
        // fixed = 30*2e-3 = 0.06; per_side = 0.25*30*1e-3 = 7.5e-3
        // max = 1 + (0.94/0.0075) = 1 + 125
        assert_eq!(m.max_agents_compute().unwrap(), 126);
        assert!(m.utilization(126).unwrap() <= 1.0 + 1e-9);
        assert!(m.utilization(130).unwrap() > 1.0);
    }

    #[test]
    fn degenerate_inputs_yield_typed_errors_not_nan() {
        let mut zero_b = model(4e-3);
        zero_b.compute.batch_width = 0;
        assert_eq!(zero_b.utilization(10), Err(CapacityError::ZeroBatchWidth));
        assert_eq!(zero_b.curve(100).unwrap_err(), CapacityError::ZeroBatchWidth);
        assert_eq!(zero_b.limit().unwrap_err(), CapacityError::ZeroBatchWidth);

        let mut bad_rate = model(4e-3);
        bad_rate.main_rate = 0.0;
        assert_eq!(
            bad_rate.utilization(10),
            Err(CapacityError::NonPositiveMainRate(0.0))
        );
        bad_rate.main_rate = -3.0;
        assert_eq!(
            bad_rate.max_agents_compute(),
            Err(CapacityError::NonPositiveMainRate(-3.0))
        );
        bad_rate.main_rate = f64::NAN;
        assert!(matches!(
            bad_rate.utilization(10),
            Err(CapacityError::NonPositiveMainRate(_))
        ));

        let mut bad_duty = model(4e-3);
        bad_duty.side_duty = -0.5;
        assert_eq!(
            bad_duty.evaluate(10).unwrap_err(),
            CapacityError::NegativeSideDuty(-0.5)
        );

        let mut bad_cost = model(f64::INFINITY);
        assert!(matches!(
            bad_cost.utilization_fused(10),
            Err(CapacityError::NonFiniteCost { which: "t_side_batch", .. })
        ));
        bad_cost.compute.t_side_batch = 1e-3;
        bad_cost.compute.t_main_decode = -1.0;
        assert!(matches!(
            bad_cost.utilization(10),
            Err(CapacityError::NonFiniteCost { which: "t_main_decode", .. })
        ));
        // every error renders a human-readable reason
        assert!(format!("{}", CapacityError::ZeroBatchWidth).contains("batch_width"));
    }

    #[test]
    fn fused_ticks_raise_the_compute_ceiling() {
        // Widen the batch so the per-token main op dominates the serial
        // model; fusing main into the batch removes that term entirely.
        let mut m = model(4e-3);
        m.compute.batch_width = 16;
        let serial = m.max_agents_compute().unwrap();
        let fused = m.max_agents_compute_fused().unwrap();
        assert!(
            fused > serial,
            "fused ceiling {fused} must exceed serial {serial}"
        );
        // At the serial ceiling the fused stream still has headroom.
        assert!(m.utilization_fused(serial).unwrap() < 1.0);
        // Fused utilization is flat until the population fills one batch
        // (ops per main token floored at 1), then grows linearly.
        let floor = m.utilization_fused(1).unwrap();
        assert_eq!(m.utilization_fused(2).unwrap(), floor);
        assert!(m.utilization_fused(100_000).unwrap() > 1.0);
        // zero side duty → sides are free → unbounded fused compute
        m.side_duty = 0.0;
        assert_eq!(m.max_agents_compute_fused().unwrap(), u64::MAX);
    }

    #[test]
    fn multi_session_model_generalizes_the_fused_one() {
        let m = model(4e-3);
        // One session IS the fused single-episode model.
        for n in [1u64, 2, 5, 40] {
            assert_eq!(
                m.utilization_sessions(1, n).unwrap(),
                m.utilization_fused(n).unwrap(),
                "S=1 must reduce to the fused model at n={n}"
            );
        }
        // Utilization is monotone in the session count, zero at S=0.
        assert_eq!(m.utilization_sessions(0, 5).unwrap(), 0.0);
        let mut last = 0.0;
        for s in 1..40u64 {
            let u = m.utilization_sessions(s, 5).unwrap();
            assert!(u >= last, "utilization dipped at S={s}");
            last = u;
        }
        // Exact ceiling math: b=4, t=30·4e-3=0.12, max_tokens=4/0.12=33.3;
        // n=5, duty 0.25 → per_session=2 → S_max = 16.
        assert_eq!(m.max_sessions_compute(5).unwrap(), 16);
        assert!(m.utilization_sessions(16, 5).unwrap() <= 1.0 + 1e-9);
        assert!(m.utilization_sessions(18, 5).unwrap() > 1.0);
        // More side agents per session → fewer concurrent sessions fit.
        assert!(m.max_sessions_compute(1).unwrap() > m.max_sessions_compute(5).unwrap());
        // A device too slow for even one batch op per token serves nobody.
        let slow = model(40e-3);
        assert_eq!(slow.max_sessions_compute(5).unwrap(), 0);
        // Curve: log-spaced, classified by the same utilization.
        let curve = m.sessions_curve(100, 5).unwrap();
        assert_eq!(curve.first().unwrap().0, 1);
        assert!(curve.last().unwrap().1 > 1.0, "curve should cross saturation");
        for w in curve.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        // Degenerate inputs surface as the same typed errors.
        let mut zero_b = model(4e-3);
        zero_b.compute.batch_width = 0;
        assert_eq!(
            zero_b.utilization_sessions(4, 5).unwrap_err(),
            CapacityError::ZeroBatchWidth
        );
        assert_eq!(
            zero_b.max_sessions_compute(5).unwrap_err(),
            CapacityError::ZeroBatchWidth
        );
    }

    #[test]
    fn chunked_prefill_bounds_ttft_and_tpot() {
        let m = model(4e-3);
        // Exact tick math: 120 uncovered tokens at different budgets.
        assert_eq!(m.ttft_ticks_chunked(120, 0, 1).unwrap(), 120);
        assert_eq!(m.ttft_ticks_chunked(120, 0, 4).unwrap(), 30);
        assert_eq!(m.ttft_ticks_chunked(120, 0, 7).unwrap(), 18); // ceiling
        // Registry coverage is free TTFT: 96 adopted rows leave 24 lanes.
        assert_eq!(m.ttft_ticks_chunked(120, 96, 4).unwrap(), 6);
        // The final token always decodes live, even under full coverage.
        assert_eq!(m.ttft_ticks_chunked(120, 120, 4).unwrap(), 1);
        // Seconds charge one fused op per tick.
        assert_eq!(m.ttft_seconds_chunked(120, 0, 4).unwrap(), 30.0 * 4e-3);
        // Budget 0 is a typed error, not a prompt that never finishes.
        assert_eq!(
            m.ttft_ticks_chunked(120, 0, 0),
            Err(CapacityError::ZeroPrefillBudget)
        );
        assert!(format!("{}", CapacityError::ZeroPrefillBudget).contains("prefill_budget"));
        // TPOT: the chunked stall bound is a constant; the monolithic one
        // scales with the prompt and overtakes it past two batches' worth.
        assert_eq!(m.tpot_stall_chunked_ticks(), 2.0);
        assert!(
            m.tpot_stall_monolithic_ticks(120).unwrap() > m.tpot_stall_chunked_ticks(),
            "a long prompt must stall more monolithically than chunked"
        );
        assert!(m.tpot_stall_monolithic_ticks(4).unwrap() <= m.tpot_stall_chunked_ticks());
        // The dial: TTFT falls monotonically with budget, stall stays flat.
        let curve = m.prefill_curve(120, 0, 8).unwrap();
        assert_eq!(curve.len(), 8);
        for w in curve.windows(2) {
            assert!(w[1].ttft_ticks <= w[0].ttft_ticks, "TTFT rose with budget");
            assert_eq!(w[1].tpot_stall_ticks, w[0].tpot_stall_ticks);
        }
        assert_eq!(curve[0].ttft_ticks, 120);
        assert_eq!(curve[7].ttft_ticks, 15);
    }

    #[test]
    fn limit_reports_binding_constraint() {
        // slow device → compute binds before memory
        let slow = model(4e-3);
        let (n, why) = slow.limit().unwrap();
        assert_eq!(why, Bottleneck::Compute);
        assert!(n < slow.max_agents_memory());

        // very fast device → memory binds
        let fast = model(1e-7);
        let (n, why) = fast.limit().unwrap();
        assert_eq!(why, Bottleneck::Memory);
        assert_eq!(n, fast.max_agents_memory());
        assert!(n > 1000, "paper's 1000+ agent claim should hold: {n}");
    }

    #[test]
    fn curve_is_monotone_and_classified() {
        let m = model(4e-3);
        let curve = m.curve(100_000).unwrap();
        for w in curve.windows(2) {
            assert!(w[1].mem_bytes >= w[0].mem_bytes);
            assert!(w[1].utilization >= w[0].utilization);
        }
        assert_eq!(curve.first().unwrap().bottleneck, Bottleneck::Feasible);
        assert_ne!(curve.last().unwrap().bottleneck, Bottleneck::Feasible);
    }

    #[test]
    fn quantized_tier_extends_the_memory_ceiling() {
        // fast device → memory binds, so the tier is the lever that matters
        let fast = model(1e-7);
        assert!(fast.max_agents_memory_q8() > fast.max_agents_memory());
        let (n32, why32) = fast.limit().unwrap();
        let (nq8, why8) = fast.limit_q8().unwrap();
        assert_eq!(why32, Bottleneck::Memory);
        assert_eq!(why8, Bottleneck::Memory);
        assert!(nq8 > n32, "quantized tier must admit more agents: {nq8} vs {n32}");
        // Just past the fp32 ceiling the quantized tier is still feasible.
        let past = fast.evaluate(n32 + 1).unwrap();
        assert_eq!(past.bottleneck, Bottleneck::Memory);
        let past_q8 = fast.evaluate_q8(n32 + 1).unwrap();
        assert_eq!(past_q8.bottleneck, Bottleneck::Feasible);
        // The tier changes memory charges only — compute is tier-blind.
        assert_eq!(past_q8.utilization, past.utilization);
        assert!(past_q8.mem_bytes < past.mem_bytes);
        // The q8 curve is classified by the same machinery.
        let curve = fast.curve_q8(100_000).unwrap();
        assert_eq!(curve.first().unwrap().bottleneck, Bottleneck::Feasible);
        assert_ne!(curve.last().unwrap().bottleneck, Bottleneck::Feasible);
        // A compute-bound model gains nothing from the tier.
        let slow = model(4e-3);
        assert_eq!(slow.limit_q8().unwrap(), slow.limit().unwrap());
    }

    #[test]
    fn million_agents_is_memory_bound_on_one_card() {
        // The title's "million-agent" scaling: even with zero compute cost,
        // one 24 GB card cannot hold 1M × (synapse + overhead) — the model
        // quantifies exactly how far the memory axis carries.
        let free = model(0.0);
        assert_eq!(free.max_agents_compute().unwrap(), u64::MAX);
        let at_million = free.evaluate(1_000_000).unwrap();
        assert_eq!(at_million.bottleneck, Bottleneck::Memory);
        // ... unless the per-agent footprint drops to the synapse-only row
        // the paper's Table 1 quotes (≈0.8 MB): then ~28k agents/card, and
        // a million agents is a ~36-card (not data-center) problem.
        let mut slim = free.clone();
        slim.mem.per_agent_overhead = 0;
        slim.mem.side_gen = 0;
        let per_card = slim.max_agents_memory();
        assert!(per_card > 20_000, "{per_card}");
        assert!((1_000_000 / per_card) < 50);
    }
}
