//! The paper's Layer-3 contribution: the Warp-Cortex coordinator.
//!
//! | module      | paper § | mechanism |
//! |-------------|---------|-----------|
//! | `prism`     | 3.2     | Singleton Weight Sharing + agent registry; rents pool-backed caches and wires resident-block accounting |
//! | `synapse`   | 3.3     | Topological Synapse (shared landmark buffer; seeds side caches in place via `seed_into`) |
//! | `router`    | 3.4     | Cortex Router (streaming trigger extraction) |
//! | `gate`      | 3.5     | Validation Gate (cosine θ-test) |
//! | `inject`    | 3.6     | Referential Injection (virtual-position KV) |
//! | `scheduler` | 3.1     | River & Stream worker pool (+ device lanes) |
//! | `batcher`   | 4       | dynamic batching of side-agent decode steps |
//! | `memory`    | 5       | Table-1/Table-2 byte accounting (resident-block bytes) + projection |
//! | `baseline`  | 5       | the Standard Architecture comparison column |
//! | `cortex`    | Fig. 1  | the assembled orchestrator; governs the shared [`crate::model::KvPool`] and its knobs |
//!
//! Context memory is demand-paged: there is exactly one
//! [`crate::model::KvPool`] per engine, the orchestrator adopts it and
//! applies the capacity/reclaim limits from [`CortexConfig::kv_pool`]
//! (paging granularity is fixed at engine construction), every agent cache
//! is a block-table view into it, and finished side agents return their
//! blocks for immediate reuse.
//!
//! Common prefixes are shared copy-on-write: the pool keeps a
//! content-addressed registry of full blocks (prompt token chains via
//! `Engine::prefill_shared`, landmark seeds via `Synapse::seed_into`), so
//! spawning N agents from one prefix costs one cold fill plus O(1) blocks —
//! later agents attach the registered blocks by reference, any write into a
//! shared block copies it first, and parked entries (refcount 0) are
//! LRU-evicted only under the pool's `max_blocks` cap.  Accounting follows
//! ownership: per-agent charges (`MainKv`/`SideKv`) cover private blocks
//! only, while registry-shared blocks are charged once globally
//! (`SharedKv`) — Table 2 counts every physical block exactly once.  The
//! registry's hit/miss/evict/CoW gauges surface on
//! [`crate::model::PoolStats`] and the `/stats` endpoint.

pub mod agent;
pub mod batcher;
pub mod baseline;
pub mod capacity;
pub mod cortex;
pub mod gate;
pub mod inject;
pub mod memory;
pub mod prism;
pub mod router;
pub mod scheduler;
pub mod synapse;

pub use agent::{SideContext, SideOutcome, SideTask};
pub use batcher::Batcher;
pub use baseline::StandardArchitecture;
pub use capacity::{Bottleneck, CapacityModel, ComputeCosts};
pub use cortex::{CortexConfig, EpisodeReport, Event, WarpCortex};
pub use gate::{Gate, GateDecision};
pub use inject::Injector;
pub use memory::{MemKind, MemoryModel, MemoryTracker};
pub use prism::{AgentKind, AgentTicket, Prism};
pub use router::{AgentRole, Router, RouterConfig, Trigger};
pub use scheduler::{StreamScheduler, TaskRunner};
pub use synapse::{adaptive_subset, SeedMode, Synapse, SynapseSnapshot};
