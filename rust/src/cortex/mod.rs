//! The paper's Layer-3 contribution: the Warp-Cortex coordinator.
//!
//! | module      | paper § | mechanism |
//! |-------------|---------|-----------|
//! | `prism`     | 3.2     | Singleton Weight Sharing + agent registry; rents pool-backed caches and wires resident-block accounting |
//! | `synapse`   | 3.3     | Topological Synapse (shared landmark buffer; seeds side caches in place via `seed_into`) |
//! | `router`    | 3.4     | Cortex Router (streaming trigger extraction) |
//! | `gate`      | 3.5     | Validation Gate (cosine θ-test) |
//! | `inject`    | 3.6     | Referential Injection (virtual-position KV) |
//! | `step`      | 3.1, 4  | the step scheduler: iteration-level continuous batching of ALL decode (main + side) into fused per-tick device ops |
//! | `scheduler` | 3.1     | legacy River & Stream worker pool (kept for the thread-per-agent path) |
//! | `batcher`   | 4       | legacy linger-based dynamic batcher (subsumed by `step` on the serving path) |
//! | `memory`    | 5       | Table-1/Table-2 byte accounting (resident-block bytes) + projection |
//! | `baseline`  | 5       | the Standard Architecture comparison column |
//! | `store`     | 5       | durable session tier: crash-safe single-file checkpoint store behind hibernate / resume / preempt-to-disk |
//! | `cortex`    | Fig. 1  | the assembled orchestrator; governs the shared [`crate::model::KvPool`] and its knobs |
//!
//! Context memory is demand-paged: there is exactly one
//! [`crate::model::KvPool`] per engine, the orchestrator adopts it and
//! applies the capacity/reclaim limits from [`CortexConfig::kv_pool`]
//! (paging granularity is fixed at engine construction), every agent cache
//! is a block-table view into it, and finished side agents return their
//! blocks for immediate reuse.
//!
//! Decode scheduling is tick-based since PR 4, and **multi-session**
//! since PR 5: the River/Stream lanes survive as *priorities inside a
//! fused tick*, not as separate op streams.  Every tick the
//! [`step::StepScheduler`] collects the next token from every runnable
//! agent — the pending main step of EVERY admitted session plus one item
//! per live side agent — and issues ONE `decode_batch` op over their
//! paged block tables (fusable mains ride the leading lanes at River
//! priority while their contexts fit a side lane; a main that has
//! outgrown a lane runs as its own River op *ahead of* the side batch,
//! so no main is ever queued behind side work).  Side tasks park FIFO
//! when the batch width or the pool occupancy is saturated and are
//! re-admitted the moment a slot frees — device ops per generated token
//! fall from ~1.0 toward 1/B as the population grows
//! (`benches/continuous_batch.rs` asserts this; the `/stats` endpoint
//! exposes the tick/occupancy/park gauges live).
//!
//! The episode → **session** vocabulary: an *episode* is one prompt's
//! full generation; a *session* ([`cortex::CortexSession`], opened via
//! `WarpCortex::open_session`) is an episode as a schedulable unit — an
//! incremental state machine advancing one token per call, so S
//! concurrent requests interleave on the same fused tick loop instead of
//! serializing one blocked thread each (`run_episode` survives as a thin
//! open/loop/finish wrapper).  Session admission is FIFO under
//! [`cortex::CortexConfig::max_sessions`] and a KV-pool headroom gate
//! (with a [`crate::model::KvPool::reserve`] reservation covering the
//! admit→prefill window); beyond `max_parked_sessions` requests shed.
//! Each session's side tasks carry its id ([`agent::SideTask::session`])
//! and their outcomes route back to that session only — a disconnected
//! session's outcomes are discarded, never leaked to another request.
//! `benches/multi_session.rs` pins the payoff (ops/token at 8 sessions ≤
//! 0.6× one session) and the step.rs proptests pin bit-identical
//! equivalence to sequential episodes; [`capacity`] models the session
//! axis (`utilization_sessions`/`max_sessions_compute`).
//!
//! Prompt **prefill is chunked** since PR 6: once other sessions are
//! decoding, `open_session` no longer runs one monolithic prefill before
//! joining the tick loop — the session opens in a prefill→decode state
//! machine ([`crate::model::ChunkedPrefill`] held inside
//! [`cortex::CortexSession`]) whose teacher-forced chunks ride the same
//! fused tick as everyone else's decode lanes, budgeted by
//! [`step::StepConfig::prefill_budget`] and fair-interleaved so a
//! decode-saturated table cannot starve prefill (bounded TTFT) and a
//! long prompt adds at most one op to any tick (bounded TPOT —
//! `benches/prefill_interleave.rs` gates p99 ops/tick ≤ 2; [`capacity`]
//! models the TTFT-vs-budget curve via `ttft_ticks_chunked` /
//! `prefill_curve`).  Completed chunks register in the prefix registry
//! *incrementally*, so a concurrent identical prompt adopts blocks while
//! its twin is still prefilling (the pool's `prefix_mid_hits` gauge and
//! the `/stats` `prefill` block expose this live).
//!
//! Common prefixes are shared copy-on-write: the pool keeps a
//! content-addressed registry of full blocks (prompt token chains via
//! `Engine::prefill_shared`, landmark seeds via `Synapse::seed_into`), so
//! spawning N agents from one prefix costs one cold fill plus O(1) blocks —
//! later agents attach the registered blocks by reference, any write into a
//! shared block copies it first, and parked entries (refcount 0) are
//! LRU-evicted only under the pool's `max_blocks` cap.  Accounting follows
//! ownership: per-agent charges (`MainKv`/`SideKv`) cover private blocks
//! only, while registry-shared blocks are charged once globally
//! (`SharedKv`) — Table 2 counts every physical block exactly once.  The
//! registry's hit/miss/evict/CoW gauges surface on
//! [`crate::model::PoolStats`] and the `/stats` endpoint.
//!
//! # Memory tiers
//!
//! KV blocks occupy one of four tiers (see [`crate::architecture`] for
//! the operator-facing walkthrough), and every block's budget charge
//! follows it:
//!
//! | tier | representation | who lives here | cost/block |
//! |------|----------------|----------------|------------|
//! | hot  | fp32, device-resident | active caches, attached shared prefixes | `block_bytes` |
//! | warm | int8 + per-row fp32 scales ([`CortexConfig::kv_pool`] `quantize_parked`) | parked registry entries (refcount 0) | `q8_block_bytes` (~3.5× denser) |
//! | cold | verbatim payload in the host slab (`host_slab_blocks`) | parked sessions ([`cortex::CortexSession::park_to_host`]), cap-pressured registry entries | 0 device bytes |
//! | durable | CRC-checked records in the single-file [`store`] (`store_path`) | checkpointed / hibernated / preempted sessions | 0 bytes of RAM |
//!
//! Demotion: release-to-parked quantizes (lossy, bounded by max|x|/254
//! per row); cap pressure and explicit parking spill to the host slab
//! (lossless).  Promotion: gathers dequantize warm blocks transparently
//! (host and device share one dequant expression, so decode over a
//! mixed-tier table is deterministic), a write into a warm shared block
//! promotes via copy-on-write to a private fp32 copy, and cold blocks
//! page back in on registry hit, session resume, or write.  Admission
//! ([`crate::model::KvPool::can_admit`]) counts parked entries as
//! reclaimable headroom, so sessions shed only when the hot tier AND
//! both parking tiers are exhausted; [`capacity`] projects the tier's
//! Table-1/2 effect (`evaluate_q8`/`limit_q8`/`curve_q8`) and
//! `benches/tiered_kv.rs` gates density, admission, and bit-identical
//! park→resume in CI.  Accounting stays once-per-byte: warm parked
//! registry bytes under `SharedKv` at their quantized size, cold
//! payloads under `HostKv`, with the swap conservation law
//! (`swap_out == swap_in + swap_dropped + host_slab_bytes`) re-proved by
//! the invariant sanitizer.
//!
//! Sessions are **durable** since PR 10: with
//! [`cortex::CortexConfig::store_path`] set, the fourth tier gives a
//! session a life beyond its TCP connection.  The lifecycle:
//! [`cortex::CortexSession::checkpoint`] commits a crash-safe record
//! (identity, sampler RNG state, last logits, and the block chain split
//! into registry hash-chain keys + private tail rows) to the append-only
//! [`store::SessionStore`]; [`cortex::CortexSession::hibernate`]
//! checkpoints, parks the context to the cold slab, frees the admission
//! slot, and leaves the ticket resident as a *preempt-to-disk candidate*;
//! under pool pressure a new admission preempts the coldest such ticket
//! (its record is already durable) instead of shedding with 503; and
//! [`cortex::WarpCortex::resume_session`] — `POST /sessions/{id}/resume`
//! at the serve layer — rebuilds the session with bit-identical
//! next-token logits via three rebuild tiers (resident page-in /
//! registry-covered attach with zero re-prefill device ops / full
//! deterministic re-prefill).  The store's ledger obeys its own
//! conservation law (`checkpoints == resumes + superseded +
//! corrupt_records_skipped + retained`, re-proved by
//! [`store::SessionStore::check_invariants`]), and
//! `benches/durable_sessions.rs` gates the zero-re-prefill resume and
//! the preempt-for-admission path in CI.
//!
//! # Correctness tooling
//!
//! The fused-tick core is lock-based, so its correctness story is
//! mechanised rather than taken on faith.
//!
//! **Lock ranking.**  Every production mutex is a
//! [`crate::util::sync::RankedMutex`] carrying a
//! [`crate::util::sync::LockRank`].  A thread may acquire a lock only if
//! its rank is *strictly lower* than every rank it already holds
//! (acquire-descending), which makes cycles — and therefore deadlocks —
//! impossible by construction.  The hierarchy, highest (acquire first)
//! to lowest (acquire last):
//!
//! | rank | lock | guards |
//! |------|------|--------|
//! | `Registry`       (70) | `runtime::device` LIVE_DEVICES, serve accept handoff | process-wide registries |
//! | `Metrics`        (60) | `metrics` histograms / throughput windows | leaf telemetry |
//! | `PrismAgents`    (50) | `prism` agent map, `synapse` memory guard | agent bookkeeping |
//! | `SideResults`    (40) | step-loop side-outcome staging | per-tick result routing |
//! | `SessionTable`   (30) | `step` session table + gauges | admission / lifecycle |
//! | `SchedulerQueue` (20) | `step`/`scheduler`/`batcher` queues & channels | work handoff |
//! | `PoolState`      (10) | `model::pool` block state | allocation / refcounts / registry |
//! | `DeviceQueue`     (0) | `runtime::device` op queue | the one every subsystem may enqueue into last |
//!
//! Debug builds keep a per-thread stack of held ranks and panic on an
//! out-of-order acquisition, naming both ranks; release builds compile
//! the tracking away to a plain `Mutex`.  Locks are poison-tolerant: a
//! panicking agent thread cannot cascade `PoisonError` unwraps into
//! every other session (`model::pool` has the regression test).
//!
//! **Invariant sanitizer.**  Debug builds re-prove the conservation laws
//! at every tick boundary and after every mutating pool op:
//! [`crate::model::KvPool::check_invariants`] (block-state / free-list /
//! live-count / registry / shared-bytes / dev-slab laws) and
//! [`step::StepScheduler::check_invariants`] (`admitted == completed +
//! active`, `requested == admitted + rejected + parked`).  The existing
//! pool-churn / CoW / fused-scheduling / multi-session proptests call
//! both, so every randomised schedule doubles as an invariant fuzz.
//!
//! **warp-audit.**  `cargo run --bin warp-audit -- rust/src` (a required
//! CI job) is a crate-graph static analyzer ([`crate::audit`]): it lexes
//! every file into code/comment/string channels, extracts functions and
//! impl owners, builds a conservative whole-crate call graph, and runs
//! eight rules.  Five are token rules distilled from real past bugs:
//! `poison-cascade` (no `.lock().unwrap()` / `.lock().expect(...)`
//! outside `util/sync.rs`), `nan-sort` (no `partial_cmp` in comparator
//! position — use `total_cmp`), `raw-mutex` (no bare `std::sync::Mutex`
//! in decode-path modules), `panic-in-serve` (no `unwrap` / `expect`
//! / `panic!` in `serve/`), and `float-eq` (no `==` / `!=` against a
//! float expression in `model/` / `cortex/` production code — the warm
//! tier's quantize→dequantize round-trip makes exact float equality a
//! tolerance bug; compare within a bound, or on `to_bits()` where
//! bit-identity is the contract).  Three are whole-crate passes:
//! `lock-order` simulates every function's `RankedMutex` acquisitions
//! over the call graph and reports any reachable path that is not
//! strictly rank-descending, naming the full function chain — the static
//! twin of the debug-build held-rank stack, covering paths no test
//! executes; `gauge-lineage` proves every pool/step gauge both reaches
//! the `/stats` serialization and is referenced by some consistency
//! check (invariant, proptest, or `ci/thresholds.json`), so a counter
//! cannot silently become write-only fiction; `hot-tick` proves nothing
//! reachable from `step_loop` / `decode_fused` / `prefill_step` performs
//! IO, sleeps, prints, or acquires a rank above `SchedulerQueue`.  Test
//! code is exempt; a deliberate site opts out with `// audit-allow:
//! <rule>` on the same or preceding line, and the eighth rule,
//! `stale-allow`, flags any marker that no longer suppresses a real
//! finding so waivers cannot outlive their reason.
//!
//! **Who owns which invariant.**  Each law is checked by exactly one
//! *primary* mechanism, with the others as backstops:
//!
//! | invariant | static (`warp-audit`) | runtime sanitizer | proptest |
//! |-----------|----------------------|-------------------|----------|
//! | lock acquisition strictly rank-descending | `lock-order` over all reachable paths (primary) | debug held-rank stack panics on executed violations | exercised by every randomised schedule |
//! | tick loop never blocks (IO / sleep / high-rank lock) | `hot-tick` (primary, waivers audited) | — | latency benches catch regressions indirectly |
//! | pool block / byte / registry conservation | `gauge-lineage` (gauges reach `/stats` + a check) | [`crate::model::KvPool::check_invariants`] (primary) | pool-churn / CoW / tiering proptests call it |
//! | session-gauge conservation (`admitted == completed + active`, …) | `gauge-lineage` | [`step::StepScheduler::check_invariants`] (primary) | multi-session hammer reconciles `/stats` |
//! | store record conservation (`checkpoints == resumes + superseded + corrupt_records_skipped + retained`) | `gauge-lineage` | [`store::SessionStore::check_invariants`] (called by the store tests + `benches/durable_sessions.rs`) | crash-safety proptest tracks a mirror model (primary) |
//! | tick counters (`main_ticks <= ticks`) | `gauge-lineage` | `check_invariants` tick-conservation law (primary) | fused-scheduling proptests |
//! | static rank table == runtime `LockRank` | CLI exits 2 on drift (primary) | — | `rust/tests/audit_roundtrip.rs` cross-check |
//! | legacy token rules keep firing identically | the 5 rules themselves | — | round-trip vs the frozen legacy scanner |
//!
//! **Cost model.**  Rank tracking, per-op pool validation and the
//! tick-boundary checks all sit behind `debug_assertions`: debug test
//! runs pay a bounded O(blocks) scan per tick, release builds pay
//! nothing beyond the plain mutex they would have had anyway.  The
//! static passes run only in the CI `audit` job — zero runtime cost.

pub mod agent;
pub mod batcher;
pub mod baseline;
pub mod capacity;
pub mod cortex;
pub mod gate;
pub mod inject;
pub mod memory;
pub mod prism;
pub mod router;
pub mod scheduler;
pub mod step;
pub mod store;
pub mod synapse;

pub use agent::{AgentCache, SideAgent, SideContext, SideOutcome, SideTask, StepAgentCtx};
pub use batcher::Batcher;
pub use baseline::StandardArchitecture;
pub use capacity::{Bottleneck, CapacityError, CapacityModel, ComputeCosts, PrefillPoint};
pub use cortex::{
    CortexConfig, CortexSession, EpisodeReport, Event, ResumeError, SessionError, WarpCortex,
};
pub use gate::{Gate, GateDecision};
pub use inject::Injector;
pub use memory::{MemKind, MemoryModel, MemoryTracker};
pub use prism::{AgentKind, AgentTicket, Prism};
pub use router::{AgentRole, Router, RouterConfig, Trigger};
pub use scheduler::{StreamScheduler, TaskRunner};
pub use step::{
    AdmitGate, AgentSpawner, FusedExec, MainStepOut, SessionDenied, SessionPermit, SessionStats,
    StepConfig, StepScheduler, StepSeams, StepStats,
};
pub use store::{ResumeTicket, SessionCheckpoint, SessionStore, StoreError, StoreStats};
pub use synapse::{adaptive_subset, SeedMode, Synapse, SynapseSnapshot};
