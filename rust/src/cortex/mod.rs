//! The paper's Layer-3 contribution: the Warp-Cortex coordinator.
//!
//! | module      | paper § | mechanism |
//! |-------------|---------|-----------|
//! | `prism`     | 3.2     | Singleton Weight Sharing + agent registry; rents pool-backed caches and wires resident-block accounting |
//! | `synapse`   | 3.3     | Topological Synapse (shared landmark buffer; seeds side caches in place via `seed_into`) |
//! | `router`    | 3.4     | Cortex Router (streaming trigger extraction) |
//! | `gate`      | 3.5     | Validation Gate (cosine θ-test) |
//! | `inject`    | 3.6     | Referential Injection (virtual-position KV) |
//! | `step`      | 3.1, 4  | the step scheduler: iteration-level continuous batching of ALL decode (main + side) into fused per-tick device ops |
//! | `scheduler` | 3.1     | legacy River & Stream worker pool (kept for the thread-per-agent path) |
//! | `batcher`   | 4       | legacy linger-based dynamic batcher (subsumed by `step` on the serving path) |
//! | `memory`    | 5       | Table-1/Table-2 byte accounting (resident-block bytes) + projection |
//! | `baseline`  | 5       | the Standard Architecture comparison column |
//! | `cortex`    | Fig. 1  | the assembled orchestrator; governs the shared [`crate::model::KvPool`] and its knobs |
//!
//! Context memory is demand-paged: there is exactly one
//! [`crate::model::KvPool`] per engine, the orchestrator adopts it and
//! applies the capacity/reclaim limits from [`CortexConfig::kv_pool`]
//! (paging granularity is fixed at engine construction), every agent cache
//! is a block-table view into it, and finished side agents return their
//! blocks for immediate reuse.
//!
//! Decode scheduling is tick-based since PR 4, and **multi-session**
//! since PR 5: the River/Stream lanes survive as *priorities inside a
//! fused tick*, not as separate op streams.  Every tick the
//! [`step::StepScheduler`] collects the next token from every runnable
//! agent — the pending main step of EVERY admitted session plus one item
//! per live side agent — and issues ONE `decode_batch` op over their
//! paged block tables (fusable mains ride the leading lanes at River
//! priority while their contexts fit a side lane; a main that has
//! outgrown a lane runs as its own River op *ahead of* the side batch,
//! so no main is ever queued behind side work).  Side tasks park FIFO
//! when the batch width or the pool occupancy is saturated and are
//! re-admitted the moment a slot frees — device ops per generated token
//! fall from ~1.0 toward 1/B as the population grows
//! (`benches/continuous_batch.rs` asserts this; the `/stats` endpoint
//! exposes the tick/occupancy/park gauges live).
//!
//! The episode → **session** vocabulary: an *episode* is one prompt's
//! full generation; a *session* ([`cortex::CortexSession`], opened via
//! `WarpCortex::open_session`) is an episode as a schedulable unit — an
//! incremental state machine advancing one token per call, so S
//! concurrent requests interleave on the same fused tick loop instead of
//! serializing one blocked thread each (`run_episode` survives as a thin
//! open/loop/finish wrapper).  Session admission is FIFO under
//! [`cortex::CortexConfig::max_sessions`] and a KV-pool headroom gate
//! (with a [`crate::model::KvPool::reserve`] reservation covering the
//! admit→prefill window); beyond `max_parked_sessions` requests shed.
//! Each session's side tasks carry its id ([`agent::SideTask::session`])
//! and their outcomes route back to that session only — a disconnected
//! session's outcomes are discarded, never leaked to another request.
//! `benches/multi_session.rs` pins the payoff (ops/token at 8 sessions ≤
//! 0.6× one session) and the step.rs proptests pin bit-identical
//! equivalence to sequential episodes; [`capacity`] models the session
//! axis (`utilization_sessions`/`max_sessions_compute`).
//!
//! Prompt **prefill is chunked** since PR 6: once other sessions are
//! decoding, `open_session` no longer runs one monolithic prefill before
//! joining the tick loop — the session opens in a prefill→decode state
//! machine ([`crate::model::ChunkedPrefill`] held inside
//! [`cortex::CortexSession`]) whose teacher-forced chunks ride the same
//! fused tick as everyone else's decode lanes, budgeted by
//! [`step::StepConfig::prefill_budget`] and fair-interleaved so a
//! decode-saturated table cannot starve prefill (bounded TTFT) and a
//! long prompt adds at most one op to any tick (bounded TPOT —
//! `benches/prefill_interleave.rs` gates p99 ops/tick ≤ 2; [`capacity`]
//! models the TTFT-vs-budget curve via `ttft_ticks_chunked` /
//! `prefill_curve`).  Completed chunks register in the prefix registry
//! *incrementally*, so a concurrent identical prompt adopts blocks while
//! its twin is still prefilling (the pool's `prefix_mid_hits` gauge and
//! the `/stats` `prefill` block expose this live).
//!
//! Common prefixes are shared copy-on-write: the pool keeps a
//! content-addressed registry of full blocks (prompt token chains via
//! `Engine::prefill_shared`, landmark seeds via `Synapse::seed_into`), so
//! spawning N agents from one prefix costs one cold fill plus O(1) blocks —
//! later agents attach the registered blocks by reference, any write into a
//! shared block copies it first, and parked entries (refcount 0) are
//! LRU-evicted only under the pool's `max_blocks` cap.  Accounting follows
//! ownership: per-agent charges (`MainKv`/`SideKv`) cover private blocks
//! only, while registry-shared blocks are charged once globally
//! (`SharedKv`) — Table 2 counts every physical block exactly once.  The
//! registry's hit/miss/evict/CoW gauges surface on
//! [`crate::model::PoolStats`] and the `/stats` endpoint.

pub mod agent;
pub mod batcher;
pub mod baseline;
pub mod capacity;
pub mod cortex;
pub mod gate;
pub mod inject;
pub mod memory;
pub mod prism;
pub mod router;
pub mod scheduler;
pub mod step;
pub mod synapse;

pub use agent::{AgentCache, SideAgent, SideContext, SideOutcome, SideTask, StepAgentCtx};
pub use batcher::Batcher;
pub use baseline::StandardArchitecture;
pub use capacity::{Bottleneck, CapacityError, CapacityModel, ComputeCosts, PrefillPoint};
pub use cortex::{
    CortexConfig, CortexSession, EpisodeReport, Event, SessionError, WarpCortex,
};
pub use gate::{Gate, GateDecision};
pub use inject::Injector;
pub use memory::{MemKind, MemoryModel, MemoryTracker};
pub use prism::{AgentKind, AgentTicket, Prism};
pub use router::{AgentRole, Router, RouterConfig, Trigger};
pub use scheduler::{StreamScheduler, TaskRunner};
pub use step::{
    AdmitGate, AgentSpawner, FusedExec, MainStepOut, SessionDenied, SessionPermit, SessionStats,
    StepConfig, StepScheduler, StepSeams, StepStats,
};
pub use synapse::{adaptive_subset, SeedMode, Synapse, SynapseSnapshot};
