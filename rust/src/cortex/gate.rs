//! The Validation Gate (paper §3.5, Eq. 2): geometric quality control.
//!
//! Before a side agent's thought is merged into the Main Agent's stream, the
//! gate scores the cosine similarity between the thought's last-token hidden
//! state and the Main Agent's current hidden state; thoughts below θ are
//! rejected — the paper's defence against "hallucination cascades".

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::vecmath::cosine;

/// Outcome of one gate evaluation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GateDecision {
    pub score: f32,
    pub accepted: bool,
    pub theta: f32,
}

/// Cumulative gate statistics.
#[derive(Debug, Clone, Default)]
pub struct GateStats {
    pub evaluated: u64,
    pub accepted: u64,
    pub rejected: u64,
    /// Sum of scores ×1e6 (for mean reporting without float atomics).
    pub score_sum_micros: i64,
}

impl GateStats {
    pub fn accept_rate(&self) -> f64 {
        if self.evaluated == 0 {
            0.0
        } else {
            self.accepted as f64 / self.evaluated as f64
        }
    }

    pub fn mean_score(&self) -> f64 {
        if self.evaluated == 0 {
            0.0
        } else {
            self.score_sum_micros as f64 / 1e6 / self.evaluated as f64
        }
    }
}

/// Thread-safe gate.
#[derive(Debug)]
pub struct Gate {
    theta: f32,
    evaluated: AtomicU64,
    accepted: AtomicU64,
    score_sum_micros: std::sync::atomic::AtomicI64,
}

impl Gate {
    pub fn new(theta: f32) -> Gate {
        Gate {
            theta,
            evaluated: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            score_sum_micros: std::sync::atomic::AtomicI64::new(0),
        }
    }

    pub fn theta(&self) -> f32 {
        self.theta
    }

    /// Score a thought against the Main Agent's current hidden state.
    pub fn evaluate(&self, main_hidden: &[f32], thought_hidden: &[f32]) -> GateDecision {
        let score = cosine(main_hidden, thought_hidden);
        let accepted = score >= self.theta;
        self.evaluated.fetch_add(1, Ordering::Relaxed);
        if accepted {
            self.accepted.fetch_add(1, Ordering::Relaxed);
        }
        self.score_sum_micros
            .fetch_add((score as f64 * 1e6) as i64, Ordering::Relaxed);
        GateDecision {
            score,
            accepted,
            theta: self.theta,
        }
    }

    pub fn stats(&self) -> GateStats {
        let evaluated = self.evaluated.load(Ordering::Relaxed);
        let accepted = self.accepted.load(Ordering::Relaxed);
        GateStats {
            evaluated,
            accepted,
            rejected: evaluated - accepted,
            score_sum_micros: self.score_sum_micros.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn accepts_aligned_rejects_orthogonal() {
        let g = Gate::new(0.5);
        let main = vec![1.0, 0.0, 0.0, 0.0];
        let aligned = vec![0.9, 0.1, 0.0, 0.0];
        let orthogonal = vec![0.0, 0.0, 1.0, 0.0];
        let opposite = vec![-1.0, 0.0, 0.0, 0.0];
        assert!(g.evaluate(&main, &aligned).accepted);
        assert!(!g.evaluate(&main, &orthogonal).accepted);
        assert!(!g.evaluate(&main, &opposite).accepted);
        let s = g.stats();
        assert_eq!(s.evaluated, 3);
        assert_eq!(s.accepted, 1);
        assert!((s.accept_rate() - 1.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn theta_zero_accepts_nonnegative_theta_one_only_identical() {
        let main = vec![0.3, -0.2, 0.9];
        let g0 = Gate::new(0.0);
        assert!(g0.evaluate(&main, &main).accepted);
        let g1 = Gate::new(0.9999);
        assert!(g1.evaluate(&main, &main).accepted);
        assert!(!g1.evaluate(&main, &[0.3, 0.2, 0.9]).accepted);
    }

    #[test]
    fn score_is_bounded_and_symmetric() {
        check("gate score bounded", 200, |g| {
            let n = g.usize_in(1..64);
            let a: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| g.f32_in(-2.0, 2.0)).collect();
            let gate = Gate::new(0.5);
            let d1 = gate.evaluate(&a, &b);
            let d2 = gate.evaluate(&b, &a);
            crate::prop_assert!(
                d1.score >= -1.0 - 1e-5 && d1.score <= 1.0 + 1e-5,
                "score out of range: {}", d1.score
            );
            crate::prop_assert!(
                (d1.score - d2.score).abs() < 1e-5,
                "asymmetric: {} vs {}", d1.score, d2.score
            );
            Ok(())
        });
    }
}
