//! The step scheduler: iteration-level continuous batching for main and
//! side decode (the PR-4 tentpole), generalized to **S concurrent
//! sessions** (the PR-5 tentpole).
//!
//! The pre-PR-4 topology gave the device a *serial* op stream: the main
//! agent issued one blocking decode op per token from the episode thread,
//! while side agents funnelled through the linger-based [`super::batcher`]
//! on their own worker threads.  `capacity.rs`'s utilization model showed
//! compute — not memory — had become the binding constraint on the paper's
//! ">1,000 agents" claim.  The fix is the serving classic (vLLM-style
//! continuous batching, at iteration granularity): one device-feeding loop
//! that, every tick,
//!
//! 1. collects the next-token work item from every runnable agent — the
//!    pending main step of EVERY admitted session (the session table; a
//!    bounded cross-session gather window lets rate-matched sessions land
//!    in the same tick) plus one `(token, pos, block-table)` item per
//!    live side agent (side agents are *pollable state machines*
//!    ([`super::agent::SideAgent`]), not blocked threads),
//! 2. fuses them into one [`crate::model::Engine::decode_fused`] call
//!    over O(k) paged block tables (fusable mains ride the leading lanes
//!    of the batch program at River priority while their contexts fit;
//!    outgrown mains run as their own River ops *ahead of* the side
//!    batch — a main is never queued behind side work, only behind other
//!    mains when fusable mains exceed the width: `main_deferred`),
//! 3. fans results back: each main reply through its per-request
//!    completion channel, side rows fed straight into each agent's state
//!    machine, side outcomes routed to the owning session's queue.
//!
//! Admission is capacity-aware and continuous on BOTH axes.  Side tasks
//! park in a FIFO queue and are admitted only while the live-agent count
//! is under `max_active` AND the admission gate (pool occupancy, in
//! production) says a fresh side cache still fits; a finishing agent's
//! slot is refilled on the *very next tick*.  Sessions ([`SessionPermit`]
//! via [`StepScheduler::open_session`]) admit FIFO under `max_sessions`
//! and the session gate (prefill headroom, in production), park up to
//! `max_parked_sessions`, and shed with [`SessionDenied::QueueFull`]
//! beyond that — a disconnecting session (permit drop) frees its slot
//! immediately and its undelivered outcomes are discarded.
//!
//! The scheduler is engine-agnostic behind the [`StepSeams`] — the fused
//! executor, the agent spawner and the two admission gates — so the
//! fused-vs-sequential and multi-session equivalence proptests below and
//! `benches/continuous_batch.rs`/`benches/multi_session.rs` drive the
//! full admit/park/disconnect protocol host-only.  All locks on the
//! request path are poison-tolerant ([`crate::util::sync`]): one
//! panicking caller surfaces as its own `Err`, it does not wedge every
//! later request.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::agent::{SideAgent, SideOutcome, SideState, SideTask};
use crate::model::{FusedOut, FusedReq, KvCache, MainLane, PagedKv, RawDecode};
use crate::util::sync::{ranked_wait_timeout, LockRank, RankedMutex};

/// The fused decode executor: `(main lanes, side items, fuse_main)` → one
/// tick's results.  Since the multi-session generalisation a tick carries
/// one main lane per concurrent session.  Production wraps
/// [`crate::model::Engine::decode_fused`]; tests and the
/// continuous-batching benches inject deterministic host-only stubs.
pub type FusedExec =
    Arc<dyn Fn(&[MainLane], &[FusedReq], bool) -> Result<FusedOut> + Send + Sync>;

/// Builds a live [`SideAgent`] for an admitted task.  Production wraps
/// [`SideAgent::spawn`] (prism registration + synapse seeding); tests use
/// [`SideAgent::from_parts`] over bare pool caches.
pub type AgentSpawner = Arc<dyn Fn(SideTask) -> SideAgent + Send + Sync>;

/// Capacity gate consulted before each admission: `false` parks the task
/// (retried every tick).  Production checks pool occupancy — a fresh
/// side cache's worst-case blocks must still fit under `max_blocks`.
pub type AdmitGate = Arc<dyn Fn() -> bool + Send + Sync>;

/// A runtime invariant sanitizer the tick loop runs at every tick
/// boundary in debug builds: returns the violated conservation laws (by
/// name) or `Ok`.  Production wires this to
/// [`crate::model::KvPool::check_invariants`].
pub type InvariantCheck = Arc<dyn Fn() -> std::result::Result<(), String> + Send + Sync>;

/// The scheduler's injectable seams, bundled: the fused executor, the
/// side-agent spawner, and the two capacity gates (side-task admission and
/// session admission).  [`StepSeams::new`] defaults both gates to
/// always-admit; production wires them to [`crate::model::KvPool`]
/// headroom checks.
pub struct StepSeams {
    pub exec: FusedExec,
    pub spawner: AgentSpawner,
    /// Consulted before each side-task admission.
    pub admit: AdmitGate,
    /// Consulted before each *session* admission (a main stream's worst
    /// case prefill blocks must still fit).  The production gate is
    /// [`crate::model::KvPool::can_admit`], which counts *tiered*
    /// headroom: free blocks, plus parked registry entries that would
    /// re-quantize or spill to the host slab under pressure — a session
    /// is shed only when the hot tier AND both parking tiers are
    /// exhausted.
    pub session_admit: AdmitGate,
    /// Optional tick-boundary sanitizer, run after each tick's sweep in
    /// debug builds only (release ticks pay nothing).  A violation
    /// panics the loop — in debug, corrupted bookkeeping is a bug to
    /// surface at the tick that caused it, not to serve on.
    pub invariants: Option<InvariantCheck>,
}

impl StepSeams {
    pub fn new(exec: FusedExec, spawner: AgentSpawner) -> StepSeams {
        StepSeams {
            exec,
            spawner,
            admit: Arc::new(|| true),
            session_admit: Arc::new(|| true),
            invariants: None,
        }
    }
}

/// Scheduler knobs (production values are derived from
/// [`super::CortexConfig`] and the engine capacities).
#[derive(Debug, Clone)]
pub struct StepConfig {
    /// Lanes of the compiled batch program (`caps.decode_batch`): the hard
    /// per-tick fusion width.
    pub batch_width: usize,
    /// Rows one batch lane can hold (`caps.side_ctx`).  Decides whether a
    /// pending main step can ride a batch lane (`len + 1 <= side_ctx`); a
    /// main that has outgrown a lane runs as its own op and reserves NO
    /// lane — sides keep the full width.
    pub side_ctx: usize,
    /// Max concurrently *decoding* side agents; beyond this, tasks park.
    pub max_active: usize,
    /// Max parked tasks beyond the active ones (submit backpressure).
    pub max_parked: usize,
    /// Ride main steps on the leading lanes of the batch program while
    /// their contexts fit a side-capacity lane (one device op per tick).
    /// Off = every main step runs as its own River op ahead of the side
    /// batch.
    pub fuse_main: bool,
    /// Concurrent admitted sessions (main streams).  `open_session` calls
    /// beyond this park FIFO until a session closes.  Clamped to ≥ 1.
    pub max_sessions: usize,
    /// Sessions allowed to wait for admission before `open_session`
    /// rejects outright (load shedding — HTTP 503 at the serve layer).
    pub max_parked_sessions: usize,
    /// Cross-session gather window: when fewer mains are queued than there
    /// are admitted sessions, wait up to this long for the other sessions'
    /// concurrent steps before running the tick, so S sessions share one
    /// fused op instead of S serial ones.  Zero = tick immediately.  The
    /// window only ever delays a tick that would under-fill its main
    /// lanes, and is negligible against a real device op.
    pub main_gather: Duration,
    /// Teacher-forced prefill lanes admitted into each fused tick
    /// ([`StepScheduler::prefill_step`]) — the TTFT-vs-TPOT dial: a long
    /// prompt admits immediately and trickles into the shared tick at this
    /// rate instead of stalling every session behind one monolithic
    /// prefill op.  Prefill lanes ride behind decode mains (a pending
    /// fusable prefill chunk is ceded a batch lane on alternating ticks
    /// when decode would otherwise monopolize the width), so with budget
    /// `b` a prefilling prompt adds at most `b` lanes — and, once its
    /// context outgrows a batch lane, at most `b` extra own-ops — to any
    /// tick.  Clamped to ≥ 1: budget 0 would park prefills forever.
    pub prefill_budget: usize,
}

impl Default for StepConfig {
    fn default() -> StepConfig {
        StepConfig {
            batch_width: 1,
            side_ctx: 64,
            max_active: 4,
            max_parked: 16,
            fuse_main: true,
            max_sessions: 8,
            max_parked_sessions: 32,
            main_gather: Duration::from_micros(200),
            prefill_budget: 2,
        }
    }
}

/// Result of one main-agent step routed through the scheduler.
#[derive(Debug)]
pub struct MainStepOut {
    pub logits: Vec<f32>,
    pub hidden: Vec<f32>,
}

/// Live scheduler statistics (the `/stats` `step` gauges).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// Side tasks accepted by `submit`.
    pub submitted: u64,
    /// Side-task outcomes delivered to the results channel.
    pub completed: u64,
    /// Side tasks rejected at submit (park queue full).
    pub rejected_capacity: u64,
    /// Side agents currently decoding.
    pub active: usize,
    /// Side tasks currently parked awaiting admission.
    pub parked: usize,
    /// High-water parked count.
    pub parked_peak: usize,
    /// Parked tasks admitted to the active set.
    pub admitted: u64,
    /// Fused ticks executed.
    pub ticks: u64,
    /// Device ops those ticks actually issued.
    pub device_ops: u64,
    /// Main-agent steps served.
    pub main_steps: u64,
    /// Side-agent steps served.
    pub side_steps: u64,
    /// Ticks where main steps rode the side batch in one device op.
    pub fused_ticks: u64,
    /// Ticks that served at least one main step (the session-occupancy
    /// denominator: `main_steps / main_ticks` → concurrent main streams
    /// per tick).
    pub main_ticks: u64,
    /// Main steps that had to wait a tick behind *other mains* (fusable
    /// mains beyond the lane budget — the batch width minus the one lane
    /// reserved for live side agents and, on alternating ticks, the one
    /// lane ceded to a pending prefill chunk; never behind the side queue
    /// itself).
    pub main_deferred: u64,
    /// Teacher-forced prefill lanes served (chunked-prefill chunks).
    pub prefill_steps: u64,
    /// Ticks that carried at least one prefill lane.
    pub prefill_ticks: u64,
    /// Prefill lanes left queued for a tick by the per-tick budget or the
    /// lane cap (the budget-deferred tokens of the `/stats` prefill block).
    pub prefill_deferred: u64,
}

impl StepStats {
    /// Device ops per generated token — the continuous-batching figure of
    /// merit: ~1.0 for the serial pre-PR-4 path, → 1/B as the population
    /// grows.
    pub fn ops_per_token(&self) -> f64 {
        let tokens = self.main_steps + self.side_steps + self.prefill_steps;
        if tokens == 0 {
            0.0
        } else {
            self.device_ops as f64 / tokens as f64
        }
    }

    /// Mean decoded tokens per device op (the batch-occupancy gauge;
    /// inverse of [`StepStats::ops_per_token`]).  Prefill lanes count as
    /// tokens: a teacher-forced chunk is a decoded row like any other.
    pub fn batch_occupancy(&self) -> f64 {
        if self.device_ops == 0 {
            0.0
        } else {
            (self.main_steps + self.side_steps + self.prefill_steps) as f64
                / self.device_ops as f64
        }
    }
}

/// Why a session admission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionDenied {
    /// The session park queue is full — shed load (HTTP 503 upstream).
    QueueFull,
    /// The scheduler is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for SessionDenied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionDenied::QueueFull => write!(f, "session queue full"),
            SessionDenied::ShuttingDown => write!(f, "scheduler shutting down"),
        }
    }
}

impl std::error::Error for SessionDenied {}

/// Live session-layer statistics (the `/stats` `sessions` gauge block).
#[derive(Debug, Clone, Default)]
pub struct SessionStats {
    /// `open_session` calls.
    pub requested: u64,
    /// Sessions granted a slot (== `completed + active` at any instant).
    pub admitted: u64,
    /// Sessions refused (queue full / shutdown).  `requested ==
    /// admitted + rejected + parked` at any instant.
    pub rejected: u64,
    /// Sessions closed (permit dropped — finished or disconnected).
    pub completed: u64,
    /// Sessions currently holding a slot.
    pub active: usize,
    /// Sessions waiting FIFO for admission.
    pub parked: usize,
    /// High-water parked count.
    pub parked_peak: usize,
    /// Mean concurrent main streams per main-serving tick
    /// (`main_steps / main_ticks`): the cross-session fusion figure,
    /// → `max_sessions` under saturating load.
    pub occupancy: f64,
}

/// FIFO session admission + per-session side-outcome routing.  Shared
/// between the scheduler handle, the tick loop and every live
/// [`SessionPermit`].
struct SessionTable {
    max_sessions: usize,
    max_parked: usize,
    admit: AdmitGate,
    /// Ranked [`LockRank::SessionTable`]: held across the admission gate,
    /// which acquires the pool state (a strictly lower rank) underneath.
    state: RankedMutex<SessionWait>,
    cv: Condvar,
    /// Session ids start at 1; 0 marks legacy (sessionless) side tasks,
    /// whose outcomes go to the global results channel.
    next_id: AtomicU64,
    /// Per-session outcome queues; an entry exists exactly while the
    /// session's permit is alive.  Ranked [`LockRank::SideResults`].
    results: RankedMutex<HashMap<u64, VecDeque<SideOutcome>>>,
    results_cv: Condvar,
}

/// All session gauges live under ONE mutex so every state transition is
/// atomic with its counters — `/stats` snapshots reconcile exactly
/// (`requested == admitted + rejected + waiting`,
/// `admitted == completed + active`) at any instant, which the
/// concurrent-client hammer test asserts while sampling mid-flight.
#[derive(Default)]
struct SessionWait {
    active: usize,
    waiting: usize,
    /// FIFO tickets: `serving` is the head waiter's ticket.
    next_ticket: u64,
    serving: u64,
    closing: bool,
    requested: u64,
    admitted: u64,
    rejected: u64,
    completed: u64,
    parked_peak: usize,
}

impl SessionTable {
    fn new(max_sessions: usize, max_parked: usize, admit: AdmitGate) -> Arc<SessionTable> {
        Arc::new(SessionTable {
            max_sessions: max_sessions.max(1),
            max_parked,
            admit,
            state: RankedMutex::new(LockRank::SessionTable, SessionWait::default()),
            cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            results: RankedMutex::new(LockRank::SideResults, HashMap::new()),
            results_cv: Condvar::new(),
        })
    }

    /// Blocking FIFO admission: immediate when a slot and pool headroom are
    /// free and nobody is already waiting; otherwise parks in ticket order
    /// (re-checked on every close and on a short timeout, since the pool
    /// gate has no condvar of its own).  Associated fn because the permit
    /// must hold the table `Arc`.
    fn open(table: &Arc<SessionTable>) -> std::result::Result<SessionPermit, SessionDenied> {
        let mut st = table.state.lock();
        st.requested += 1;
        if st.closing {
            st.rejected += 1;
            return Err(SessionDenied::ShuttingDown);
        }
        if st.waiting == 0 && st.active < table.max_sessions && (table.admit)() {
            st.active += 1;
            st.admitted += 1;
            drop(st);
            return Ok(SessionTable::issue(table));
        }
        if st.waiting >= table.max_parked {
            st.rejected += 1;
            return Err(SessionDenied::QueueFull);
        }
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.waiting += 1;
        st.parked_peak = st.parked_peak.max(st.waiting);
        loop {
            if st.closing {
                st.waiting -= 1;
                st.rejected += 1;
                if st.serving == ticket {
                    // let the waiters behind this one drain in order
                    st.serving += 1;
                }
                drop(st);
                table.cv.notify_all();
                return Err(SessionDenied::ShuttingDown);
            }
            if st.serving == ticket && st.active < table.max_sessions && (table.admit)() {
                st.serving += 1;
                st.waiting -= 1;
                st.active += 1;
                st.admitted += 1;
                drop(st);
                table.cv.notify_all();
                return Ok(SessionTable::issue(table));
            }
            st = ranked_wait_timeout(&table.cv, st, Duration::from_millis(5));
        }
    }

    fn issue(table: &Arc<SessionTable>) -> SessionPermit {
        let id = table.next_id.fetch_add(1, Ordering::Relaxed);
        table.results.lock().insert(id, VecDeque::new());
        SessionPermit {
            table: table.clone(),
            id,
            shed: false,
        }
    }

    fn close(&self, id: u64, shed: bool) {
        {
            let mut st = self.state.lock();
            st.active = st.active.saturating_sub(1);
            if shed {
                // Post-admission load shed (e.g. the pool's atomic
                // reservation lost a race): reclassify as rejected so the
                // gauges reconcile AND operators alarming on `rejected`
                // actually see the 503s — the session never generated.
                st.admitted = st.admitted.saturating_sub(1);
                st.rejected += 1;
            } else {
                st.completed += 1;
            }
        }
        self.cv.notify_all();
        self.results.lock().remove(&id);
        self.results_cv.notify_all();
    }

    /// Route one outcome to its session's queue; `false` when the session
    /// has already closed (outcome dropped — its agent's blocks are freed
    /// with the agent either way).
    fn route(&self, session: u64, outcome: SideOutcome) -> bool {
        // Delivering outcomes to session queues IS the tick's job;
        // `results` is held for one push_back and released before the
        // wakeup, never across IO or another lock.
        // audit-allow: hot-tick
        let mut map = self.results.lock();
        match map.get_mut(&session) {
            Some(q) => {
                q.push_back(outcome);
                drop(map);
                self.results_cv.notify_all();
                true
            }
            None => false,
        }
    }

    fn close_all(&self) {
        self.state.lock().closing = true;
        self.cv.notify_all();
    }

    fn active_now(&self) -> usize {
        // One-field read under the session lock; the tick polls it for
        // admission headroom, bounded and lock-leaf.
        // audit-allow: hot-tick
        self.state.lock().active
    }

    /// Session-gauge conservation laws.  All counters live under the one
    /// state mutex, so a single snapshot must reconcile exactly — any
    /// drift is a lost or double-counted transition, not a race window.
    fn validate_gauges(&self) -> std::result::Result<(), String> {
        // The debug-boundary sanitizer snapshots the gauges under the
        // session lock once per tick; release builds never take this path.
        // audit-allow: hot-tick
        let st = self.state.lock();
        let admitted_rhs = st.completed + st.active as u64;
        if st.admitted != admitted_rhs {
            return Err(format!(
                "session-admission-conservation: admitted ({}) != completed ({}) + active ({})",
                st.admitted, st.completed, st.active
            ));
        }
        let requested_rhs = st.admitted + st.rejected + st.waiting as u64;
        if st.requested != requested_rhs {
            return Err(format!(
                "session-request-conservation: requested ({}) != admitted ({}) + rejected ({}) + parked ({})",
                st.requested, st.admitted, st.rejected, st.waiting
            ));
        }
        Ok(())
    }
}

/// RAII admission slot for one main stream.  Carries the session id that
/// side tasks reference ([`SideTask::session`]) so their outcomes route
/// back to this session only.  Dropping the permit closes the session:
/// the slot frees, the next parked session admits, and any undelivered
/// outcomes for this session are discarded.
pub struct SessionPermit {
    table: Arc<SessionTable>,
    id: u64,
    shed: bool,
}

impl SessionPermit {
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Consume the permit as a *load shed*: the admission is reclassified
    /// as `rejected` instead of `completed` (used when a post-admission
    /// resource grab — the pool's atomic prefill reservation — loses a
    /// race and the request answers 503 without ever generating).
    pub fn shed(mut self) {
        self.shed = true;
    }
}

impl Drop for SessionPermit {
    fn drop(&mut self) {
        self.table.close(self.id, self.shed);
    }
}

struct Gauges {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    admitted: AtomicU64,
    ticks: AtomicU64,
    device_ops: AtomicU64,
    main_steps: AtomicU64,
    side_steps: AtomicU64,
    fused_ticks: AtomicU64,
    main_ticks: AtomicU64,
    main_deferred: AtomicU64,
    prefill_steps: AtomicU64,
    prefill_ticks: AtomicU64,
    prefill_deferred: AtomicU64,
    active: AtomicUsize,
    parked: AtomicUsize,
    parked_peak: AtomicUsize,
}

impl Gauges {
    fn new() -> Gauges {
        Gauges {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            ticks: AtomicU64::new(0),
            device_ops: AtomicU64::new(0),
            main_steps: AtomicU64::new(0),
            side_steps: AtomicU64::new(0),
            fused_ticks: AtomicU64::new(0),
            main_ticks: AtomicU64::new(0),
            main_deferred: AtomicU64::new(0),
            prefill_steps: AtomicU64::new(0),
            prefill_ticks: AtomicU64::new(0),
            prefill_deferred: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            parked: AtomicUsize::new(0),
            parked_peak: AtomicUsize::new(0),
        }
    }

    /// Tasks accepted but whose outcome is not yet in the results channel.
    fn in_flight(&self) -> usize {
        let s = self.submitted.load(Ordering::SeqCst);
        let c = self.completed.load(Ordering::SeqCst);
        s.saturating_sub(c) as usize
    }
}

struct MainReq {
    token: i32,
    pos: i32,
    paged: PagedKv,
    capacity: usize,
    reply: mpsc::Sender<Result<RawDecode>>,
}

enum Cmd {
    Main(MainReq),
    /// A teacher-forced prefill chunk: same request shape as a main step,
    /// but admitted under [`StepConfig::prefill_budget`] behind decode
    /// mains instead of competing with them for every lane.
    Prefill(MainReq),
    Task(SideTask),
}

/// The unified step scheduler.  Share via `Arc`; one per [`super::WarpCortex`].
pub struct StepScheduler {
    tx: RankedMutex<Option<mpsc::Sender<Cmd>>>,
    results_rx: RankedMutex<mpsc::Receiver<SideOutcome>>,
    handle: RankedMutex<Option<std::thread::JoinHandle<()>>>,
    gauges: Arc<Gauges>,
    sessions: Arc<SessionTable>,
    max_pending: usize,
}

impl StepScheduler {
    /// Spawn the tick loop over the injected seams.  Production callers
    /// build the seams from an engine + prism/synapse (see
    /// `WarpCortex::new`); tests and benches inject host-only stubs.
    pub fn new(mut cfg: StepConfig, seams: StepSeams) -> Arc<StepScheduler> {
        let StepSeams {
            exec,
            spawner,
            admit,
            session_admit,
            invariants,
        } = seams;
        // A zero width would collect no side items while agents sit active
        // forever (a hot spin); one lane is the meaningful minimum.
        cfg.batch_width = cfg.batch_width.max(1);
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (results_tx, results_rx) = mpsc::channel::<SideOutcome>();
        let gauges = Arc::new(Gauges::new());
        let sessions =
            SessionTable::new(cfg.max_sessions, cfg.max_parked_sessions, session_admit);
        let max_pending = cfg.max_active + cfg.max_parked;
        let g = gauges.clone();
        let s = sessions.clone();
        let handle = std::thread::Builder::new()
            .name("warp-step".into())
            .spawn(move || step_loop(cfg, rx, results_tx, exec, spawner, admit, invariants, g, s))
            .expect("spawn step scheduler");
        Arc::new(StepScheduler {
            tx: RankedMutex::new(LockRank::SchedulerQueue, Some(tx)),
            results_rx: RankedMutex::new(LockRank::SchedulerQueue, results_rx),
            handle: RankedMutex::new(LockRank::SchedulerQueue, Some(handle)),
            gauges,
            sessions,
            max_pending,
        })
    }

    /// Admit one main stream (blocking FIFO; see [`StepConfig`] for the
    /// slot and queue bounds).  The permit's drop closes the session.
    pub fn open_session(&self) -> std::result::Result<SessionPermit, SessionDenied> {
        SessionTable::open(&self.sessions)
    }

    /// Non-blocking poll for finished side agents of one session.
    pub fn poll_session_results(&self, session: u64) -> Vec<SideOutcome> {
        let mut map = self.sessions.results.lock();
        map.get_mut(&session)
            .map(|q| q.drain(..).collect())
            .unwrap_or_default()
    }

    /// Blocking wait for one session's next side outcome (None on timeout
    /// or once the session is closed).
    pub fn wait_session_result(&self, session: u64, timeout: Duration) -> Option<SideOutcome> {
        let deadline = Instant::now() + timeout;
        let mut map = self.sessions.results.lock();
        loop {
            match map.get_mut(&session) {
                None => return None,
                Some(q) => {
                    if let Some(o) = q.pop_front() {
                        return Some(o);
                    }
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            map = ranked_wait_timeout(&self.sessions.results_cv, map, deadline - now);
        }
    }

    /// Session-layer gauges (the `/stats` `sessions` block).  The counter
    /// snapshot is taken under the session lock, so it reconciles exactly
    /// at any instant: `admitted == completed + active`,
    /// `requested == admitted + rejected + parked`.
    pub fn session_stats(&self) -> SessionStats {
        let main_steps = self.gauges.main_steps.load(Ordering::Relaxed);
        let main_ticks = self.gauges.main_ticks.load(Ordering::Relaxed);
        let st = self.sessions.state.lock();
        SessionStats {
            requested: st.requested,
            admitted: st.admitted,
            rejected: st.rejected,
            completed: st.completed,
            active: st.active,
            parked: st.waiting,
            parked_peak: st.parked_peak,
            occupancy: if main_ticks == 0 {
                0.0
            } else {
                main_steps as f64 / main_ticks as f64
            },
        }
    }

    /// One main-agent decode step through the scheduler (blocks until the
    /// result lands; appends the new KV row to `kv` on success).  The
    /// request ships the O(k) block table only — sound because this caller
    /// blocks on the reply, so the referenced blocks stay exclusively owned
    /// by `kv` for the whole step.
    pub fn main_step(&self, token: i32, pos: i32, kv: &mut KvCache) -> Result<MainStepOut> {
        if kv.remaining() == 0 {
            bail!("main_step: kv cache full");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = MainReq {
            token,
            pos,
            paged: kv.paged(),
            capacity: kv.capacity(),
            reply: reply_tx,
        };
        let tx = self.tx.lock()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("step scheduler shut down"))?;
        tx.send(Cmd::Main(req))
            .map_err(|_| anyhow!("step scheduler thread gone"))?;
        drop(tx);
        let raw = reply_rx
            .recv()
            .map_err(|_| anyhow!("step scheduler shut down while a main step was in flight"))??;
        kv.append_row(&raw.k_new, &raw.v_new)?;
        Ok(MainStepOut {
            logits: raw.logits,
            hidden: raw.hidden,
        })
    }

    /// One teacher-forced prefill step through the scheduler: the chunked
    /// admission path.  Identical round-trip to
    /// [`StepScheduler::main_step`] — blocks until the lane's result lands
    /// and appends the produced row to `kv` — but the lane rides the tick
    /// under the per-tick [`StepConfig::prefill_budget`] behind decode
    /// mains, so a long prompt prefilling chunk-by-chunk cannot stall
    /// concurrent sessions' inter-token latency.  A prefilling session
    /// calls this once per [`crate::model::ChunkedPrefill`] lane; the
    /// sequential-KV dependency (row `i` decodes over a cache of length
    /// `i`) is preserved because the caller blocks per chunk.
    pub fn prefill_step(&self, token: i32, pos: i32, kv: &mut KvCache) -> Result<MainStepOut> {
        if kv.remaining() == 0 {
            bail!("prefill_step: kv cache full");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = MainReq {
            token,
            pos,
            paged: kv.paged(),
            capacity: kv.capacity(),
            reply: reply_tx,
        };
        let tx = self.tx.lock()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("step scheduler shut down"))?;
        tx.send(Cmd::Prefill(req))
            .map_err(|_| anyhow!("step scheduler thread gone"))?;
        drop(tx);
        let raw = reply_rx.recv().map_err(|_| {
            anyhow!("step scheduler shut down while a prefill step was in flight")
        })??;
        kv.append_row(&raw.k_new, &raw.v_new)?;
        Ok(MainStepOut {
            logits: raw.logits,
            hidden: raw.hidden,
        })
    }

    /// Submit a side task; `false` means the park queue is full (caller
    /// drops it — the paper's side agents are best-effort by design).
    pub fn submit(&self, task: SideTask) -> bool {
        // Serialize the backpressure check under the tx lock; `completed`
        // only grows concurrently, which merely frees capacity.
        let guard = self.tx.lock();
        let Some(tx) = guard.as_ref() else {
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        };
        if self.gauges.in_flight() >= self.max_pending {
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // Count BEFORE sending so `in_flight()` can never under-report a
        // task the loop is already processing.
        self.gauges.submitted.fetch_add(1, Ordering::SeqCst);
        if tx.send(Cmd::Task(task)).is_err() {
            self.gauges.completed.fetch_add(1, Ordering::SeqCst); // net zero
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Non-blocking poll for finished side agents (the episode loop calls
    /// this between main steps).
    pub fn poll_results(&self) -> Vec<SideOutcome> {
        let rx = self.results_rx.lock();
        let mut out = Vec::new();
        while let Ok(r) = rx.try_recv() {
            out.push(r);
        }
        out
    }

    /// Blocking wait for the next side outcome with a timeout.
    pub fn wait_result(&self, timeout: Duration) -> Option<SideOutcome> {
        let rx = self.results_rx.lock();
        rx.recv_timeout(timeout).ok()
    }

    /// Side tasks accepted but not yet delivered as outcomes.  The loop
    /// sends every outcome *before* counting it completed, so
    /// `in_flight() == 0` guarantees the outcomes are already retrievable.
    pub fn in_flight(&self) -> usize {
        self.gauges.in_flight()
    }

    /// Wait until no side task is active or parked (or timeout).
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.in_flight() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    pub fn stats(&self) -> StepStats {
        let g = &self.gauges;
        StepStats {
            submitted: g.submitted.load(Ordering::Relaxed),
            completed: g.completed.load(Ordering::Relaxed),
            rejected_capacity: g.rejected.load(Ordering::Relaxed),
            active: g.active.load(Ordering::Relaxed),
            parked: g.parked.load(Ordering::Relaxed),
            parked_peak: g.parked_peak.load(Ordering::Relaxed),
            admitted: g.admitted.load(Ordering::Relaxed),
            ticks: g.ticks.load(Ordering::Relaxed),
            device_ops: g.device_ops.load(Ordering::Relaxed),
            main_steps: g.main_steps.load(Ordering::Relaxed),
            side_steps: g.side_steps.load(Ordering::Relaxed),
            fused_ticks: g.fused_ticks.load(Ordering::Relaxed),
            main_ticks: g.main_ticks.load(Ordering::Relaxed),
            main_deferred: g.main_deferred.load(Ordering::Relaxed),
            prefill_steps: g.prefill_steps.load(Ordering::Relaxed),
            prefill_ticks: g.prefill_ticks.load(Ordering::Relaxed),
            prefill_deferred: g.prefill_deferred.load(Ordering::Relaxed),
        }
    }

    /// Run the scheduler's conservation laws once, naming the violated law
    /// on failure.  Session gauges are snapshotted under their one mutex,
    /// so they must reconcile exactly; the side-task gauges are atomics
    /// updated from several threads, so only the monotone law
    /// (`completed <= submitted`) is sound to assert from outside the tick
    /// loop.  `completed` is loaded BEFORE `submitted`: a task completes
    /// only after it was counted submitted, so this order can never
    /// observe a transient `completed > submitted`.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        self.sessions.validate_gauges()?;
        let completed = self.gauges.completed.load(Ordering::SeqCst);
        let submitted = self.gauges.submitted.load(Ordering::SeqCst);
        if completed > submitted {
            return Err(format!(
                "side-task-conservation: completed ({completed}) > submitted ({submitted})"
            ));
        }
        // A tick is counted main-carrying only after it was counted as a
        // tick (the loop bumps `ticks` first), so loading `main_ticks`
        // before `ticks` can never observe a transient excess.
        let main_ticks = self.gauges.main_ticks.load(Ordering::Relaxed);
        let ticks = self.gauges.ticks.load(Ordering::Relaxed);
        if main_ticks > ticks {
            return Err(format!(
                "tick-conservation: main_ticks ({main_ticks}) > ticks ({ticks})"
            ));
        }
        Ok(())
    }

    /// Stop the tick loop.  In-flight main steps error out; active and
    /// parked side tasks surface as `Failed` outcomes (delivered before the
    /// loop exits, so a final `poll_results` still observes them); parked
    /// `open_session` callers wake with `ShuttingDown`.  Idempotent.
    pub fn shutdown(&self) {
        self.sessions.close_all();
        let tx = self.tx.lock().take();
        drop(tx);
        if let Some(h) = self.handle.lock().take() {
            let _ = h.join();
        }
    }
}

impl Drop for StepScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Test-only corruption hooks for the sanitizer's own coverage: each
/// breaks exactly one conservation law so the tests can assert
/// [`StepScheduler::check_invariants`] names it.  Only call while the
/// scheduler is idle — the tick loop's debug boundary check would
/// (correctly) panic on the seeded drift otherwise.
#[cfg(test)]
impl StepScheduler {
    /// Bump `admitted` without a matching session transition:
    /// `admitted == completed + active` breaks.
    fn corrupt_admitted_gauge(&self) {
        self.sessions.state.lock().admitted += 1;
    }

    /// Bump `requested` without an admit/reject/park outcome:
    /// `requested == admitted + rejected + parked` breaks.
    fn corrupt_requested_gauge(&self) {
        self.sessions.state.lock().requested += 1;
    }
}

fn deliver(
    results: &mpsc::Sender<SideOutcome>,
    sessions: &SessionTable,
    gauges: &Gauges,
    outcome: SideOutcome,
) {
    let session = outcome.task.session;
    if session == 0 {
        // Legacy (sessionless) task: the global results channel.
        let _ = results.send(outcome);
    } else {
        // Session-routed: a closed (disconnected) session's outcome is
        // dropped — it must never leak into another session's merge loop.
        let _ = sessions.route(session, outcome);
    }
    // AFTER the send: in_flight() == 0 implies the outcome is retrievable.
    gauges.completed.fetch_add(1, Ordering::SeqCst);
}

fn failed_outcome(task: SideTask, error: String) -> SideOutcome {
    SideOutcome {
        elapsed: task.spawned_at.elapsed(),
        task,
        state: SideState::Failed,
        text: String::new(),
        tokens: vec![],
        hidden: vec![],
        steps: 0,
        synapse_version: 0,
        error: Some(error),
    }
}

#[allow(clippy::too_many_lines, clippy::too_many_arguments)]
fn step_loop(
    cfg: StepConfig,
    rx: mpsc::Receiver<Cmd>,
    results: mpsc::Sender<SideOutcome>,
    exec: FusedExec,
    spawner: AgentSpawner,
    admit: AdmitGate,
    invariants: Option<InvariantCheck>,
    gauges: Arc<Gauges>,
    sessions: Arc<SessionTable>,
) {
    let mut active: Vec<SideAgent> = Vec::new();
    let mut parked: VecDeque<SideTask> = VecDeque::new();
    let mut mains: VecDeque<MainReq> = VecDeque::new();
    let mut prefills: VecDeque<MainReq> = VecDeque::new();
    // Fair-interleave bit: on alternating ticks a pending fusable prefill
    // chunk is ceded one batch lane ahead of decode mains, so under decode
    // saturation a prefilling prompt still makes ≥ 1 chunk of progress
    // every 2 ticks (and decode never loses more than 1 lane every other
    // tick to it).
    let mut prefill_turn = false;
    // Round-robin cursor so `max_active > batch_width` populations are
    // served fairly across ticks.
    let mut rr: usize = 0;
    // Gather back-off: after a full-window gather still fell short of the
    // session goal (an admitted session is idle or stalled, not
    // rate-matched), skip the next few gathers so that session taxes the
    // others by at most ~1/(1+GATHER_BACKOFF) of the window per token —
    // and probe again periodically so rate-matched populations recover.
    const GATHER_BACKOFF: u32 = 4;
    let mut gather_skip: u32 = 0;
    let mut open = true;

    fn enqueue(
        cmd: Cmd,
        mains: &mut VecDeque<MainReq>,
        prefills: &mut VecDeque<MainReq>,
        parked: &mut VecDeque<SideTask>,
    ) {
        match cmd {
            Cmd::Main(m) => mains.push_back(m),
            Cmd::Prefill(p) => prefills.push_back(p),
            Cmd::Task(t) => parked.push_back(t),
        }
    }

    loop {
        // ── 1. take on new work ─────────────────────────────────────────
        if open {
            if active.is_empty() && parked.is_empty() && mains.is_empty() && prefills.is_empty() {
                gauges.active.store(0, Ordering::Relaxed);
                gauges.parked.store(0, Ordering::Relaxed);
                // Fully idle: block until there is something to do.
                match rx.recv() {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut prefills, &mut parked),
                    Err(_) => open = false,
                }
            }
            loop {
                match rx.try_recv() {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut prefills, &mut parked),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // Cross-session gather: if fewer mains are queued than there
            // are admitted sessions, wait briefly for the other sessions'
            // concurrent steps so they share this tick's fused op instead
            // of paying one op each across consecutive ticks.  The goal
            // over-counts sessions that are idle (draining side agents,
            // stalled client sockets), so a missed window backs off before
            // probing again — an idle session must not tax every other
            // session's every token with the full wait.
            // (Gathering only pays off when mains can actually fuse:
            // with fuse_main off every main runs its own op regardless,
            // so the window would be pure latency.)
            if open && cfg.fuse_main && !mains.is_empty() && cfg.main_gather > Duration::ZERO {
                let goal = sessions.active_now().min(cfg.batch_width);
                if mains.len() >= goal {
                    gather_skip = 0;
                } else if gather_skip > 0 {
                    gather_skip -= 1;
                } else {
                    let deadline = Instant::now() + cfg.main_gather;
                    while mains.len() < goal {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(cmd) => enqueue(cmd, &mut mains, &mut prefills, &mut parked),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                open = false;
                                break;
                            }
                        }
                    }
                    gather_skip = if mains.len() < goal { GATHER_BACKOFF } else { 0 };
                }
            }
        }
        if !open {
            // Shutdown: fail everything still pending (delivered like any
            // other outcome) and exit.  Episode loops drain before the
            // orchestrator drops, so this only fires on teardown.
            for m in mains.drain(..) {
                let _ = m.reply.send(Err(anyhow!("step scheduler shut down")));
            }
            for p in prefills.drain(..) {
                let _ = p.reply.send(Err(anyhow!("step scheduler shut down")));
            }
            for t in parked.drain(..) {
                deliver(
                    &results,
                    &sessions,
                    &gauges,
                    failed_outcome(t, "step scheduler shut down".into()),
                );
            }
            for mut a in active.drain(..) {
                a.fail("step scheduler shut down".into());
                deliver(&results, &sessions, &gauges, a.into_outcome());
            }
            return;
        }

        // ── 2. continuous admission: refill freed slots every tick ──────
        while active.len() < cfg.max_active && !parked.is_empty() && admit() {
            let task = parked.pop_front().expect("parked is non-empty");
            gauges.admitted.fetch_add(1, Ordering::Relaxed);
            let agent = spawner(task);
            if agent.is_done() {
                // born-failed (registration/seeding error)
                deliver(&results, &sessions, &gauges, agent.into_outcome());
            } else {
                active.push(agent);
            }
        }
        gauges.active.store(active.len(), Ordering::Relaxed);
        gauges.parked.store(parked.len(), Ordering::Relaxed);
        gauges.parked_peak.fetch_max(parked.len(), Ordering::Relaxed);

        // ── 3. collect this tick's work items ───────────────────────────
        // Every queued session step runs this tick: fusable mains ride the
        // leading batch lanes at River priority, the rest run as their own
        // River ops ahead of the side batch.  When side agents are live,
        // one lane stays reserved for them (width permitting) so a
        // main-saturated session table cannot starve side progress
        // indefinitely — PR 4's width-1 side guarantee, generalized.
        // Fusable mains beyond the lane budget stay queued for the next
        // tick (`main_deferred`): a main only ever waits behind other
        // mains or that one reserved side lane, never behind the side
        // *queue* itself.
        // (Only *active* agents can contribute a side item this tick —
        // admission already ran — so an empty active set frees the full
        // width for mains.)
        let main_lane_cap = if active.is_empty() {
            cfg.batch_width
        } else {
            cfg.batch_width.saturating_sub(1).max(1)
        };
        // Fair interleave: when a fusable prefill chunk is pending and it
        // is prefill's turn, cede one of the main lanes to it this tick —
        // otherwise a decode-saturated session table would starve prefill
        // (unbounded TTFT), and without the alternation prefill would
        // displace a decode main every tick (stalled TPOT).
        let prefill_wants_lane = prefills
            .front()
            .is_some_and(|p| cfg.fuse_main && p.paged.len + 1 <= cfg.side_ctx);
        let decode_lane_cap = if prefill_wants_lane && prefill_turn {
            main_lane_cap.saturating_sub(1)
        } else {
            main_lane_cap
        };
        let mut tick_mains: Vec<MainReq> = Vec::new();
        let mut fused_lanes = 0usize;
        let mut overflow: VecDeque<MainReq> = VecDeque::new();
        while let Some(m) = mains.pop_front() {
            let fusable = cfg.fuse_main && m.paged.len + 1 <= cfg.side_ctx;
            if fusable && fused_lanes >= decode_lane_cap {
                overflow.push_back(m);
            } else {
                if fusable {
                    fused_lanes += 1;
                }
                tick_mains.push(m);
            }
        }
        mains = overflow;
        // Budgeted prefill admission: up to `prefill_budget` chunks ride
        // this tick.  A fusable chunk needs a free batch lane (within the
        // same side-reserving cap as mains); a chunk whose context has
        // outgrown a lane runs as its own op and takes no lane — either
        // way the per-tick cost a prefilling prompt can add is bounded by
        // the budget, not the prompt length.
        let mut tick_prefills: Vec<MainReq> = Vec::new();
        let budget = cfg.prefill_budget.max(1);
        while tick_prefills.len() < budget {
            let fusable = match prefills.front() {
                None => break,
                Some(p) => cfg.fuse_main && p.paged.len + 1 <= cfg.side_ctx,
            };
            if fusable && fused_lanes >= main_lane_cap {
                break;
            }
            let p = prefills.pop_front().expect("front exists");
            if fusable {
                fused_lanes += 1;
            }
            tick_prefills.push(p);
        }
        prefill_turn = !prefill_turn;
        let lanes: Vec<MainLane> = tick_mains
            .iter()
            .chain(tick_prefills.iter())
            .map(|m| MainLane {
                req: FusedReq {
                    token: m.token,
                    pos: m.pos,
                    paged: m.paged.clone(),
                },
                capacity: m.capacity,
            })
            .collect();
        let side_budget = cfg.batch_width.saturating_sub(fused_lanes);
        let mut idx: Vec<usize> = Vec::new();
        let mut sides: Vec<FusedReq> = Vec::new();
        let n = active.len();
        for k in 0..n {
            if sides.len() >= side_budget {
                break;
            }
            let i = (rr + k) % n;
            if let Some((token, pos)) = active[i].next_request() {
                sides.push(FusedReq {
                    token,
                    pos,
                    paged: active[i].paged(),
                });
                idx.push(i);
            }
        }
        if n > 0 {
            rr = (rr + 1) % n;
        }

        if lanes.is_empty() && sides.is_empty() {
            // Nothing runnable: sweep agents that just finished; if tasks
            // are parked behind the capacity gate, wait briefly for blocks
            // to free (or for new commands) instead of spinning hot.
            sweep_done(&mut active, &results, &sessions, &gauges);
            if active.is_empty() && !parked.is_empty() {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut prefills, &mut parked),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
            continue;
        }

        // ── 4. one fused tick ───────────────────────────────────────────
        gauges.ticks.fetch_add(1, Ordering::Relaxed);
        if !mains.is_empty() {
            // Only other *mains* ever wait a tick; never side work.
            gauges
                .main_deferred
                .fetch_add(mains.len() as u64, Ordering::Relaxed);
        }
        if !prefills.is_empty() {
            // Chunks held back by the budget or the lane cap this tick.
            gauges
                .prefill_deferred
                .fetch_add(prefills.len() as u64, Ordering::Relaxed);
        }
        // Contain executor panics like the legacy batcher: this tick's
        // participants get Err/Failed results, the loop keeps serving.
        let tick = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec(&lanes, &sides, cfg.fuse_main)
        }))
        .unwrap_or_else(|_| Err(anyhow!("fused executor panicked")));
        match tick {
            Ok(FusedOut {
                mains: main_res,
                sides: side_out,
                side_error,
                device_ops,
            }) => {
                gauges.device_ops.fetch_add(device_ops, Ordering::Relaxed);
                if !tick_mains.is_empty() {
                    gauges.main_ticks.fetch_add(1, Ordering::Relaxed);
                    if device_ops == 1 && !idx.is_empty() {
                        gauges.fused_ticks.fetch_add(1, Ordering::Relaxed);
                    }
                }
                if !tick_prefills.is_empty() {
                    gauges.prefill_ticks.fetch_add(1, Ordering::Relaxed);
                }
                // Lane results come back in submission order: decode mains
                // first, then the tick's prefill chunks.
                let mut res_it = main_res.into_iter();
                for req in tick_mains {
                    gauges.main_steps.fetch_add(1, Ordering::Relaxed);
                    // Per-lane isolation: one session's fault errs only its
                    // own step; the other sessions' replies still land.
                    let reply = match res_it.next() {
                        Some(Ok(raw)) => Ok(raw),
                        Some(Err(msg)) => Err(anyhow!("main lane failed: {msg}")),
                        None => Err(anyhow!("fused executor dropped a main lane result")),
                    };
                    let _ = req.reply.send(reply);
                }
                for req in tick_prefills {
                    gauges.prefill_steps.fetch_add(1, Ordering::Relaxed);
                    let reply = match res_it.next() {
                        Some(Ok(raw)) => Ok(raw),
                        Some(Err(msg)) => Err(anyhow!("prefill lane failed: {msg}")),
                        None => Err(anyhow!("fused executor dropped a prefill lane result")),
                    };
                    let _ = req.reply.send(reply);
                }
                if let Some(msg) = side_error {
                    // The side half of the tick failed after the main ops
                    // succeeded: fail only these lanes.
                    for slot in &idx {
                        active[*slot].fail(format!("side batch failed: {msg}"));
                    }
                } else {
                    let fed = idx.len().min(side_out.len());
                    for (slot, raw) in idx[..fed].iter().zip(side_out) {
                        gauges.side_steps.fetch_add(1, Ordering::Relaxed);
                        active[*slot].feed(raw);
                    }
                    for slot in &idx[fed..] {
                        active[*slot]
                            .fail("fused executor dropped this lane's result".into());
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in tick_mains {
                    let _ = req.reply.send(Err(anyhow!("{msg}")));
                }
                for req in tick_prefills {
                    let _ = req.reply.send(Err(anyhow!("{msg}")));
                }
                for slot in &idx {
                    active[*slot].fail(format!("fused decode failed: {msg}"));
                }
            }
        }

        // ── 5. sweep: deliver finished agents; slots refill next tick ───
        sweep_done(&mut active, &results, &sessions, &gauges);
        gauges.active.store(active.len(), Ordering::Relaxed);

        // ── 6. debug-build sanitizer: tick-boundary invariant check ─────
        // Every tick ends at a quiescent point for this loop's own state,
        // so a violated conservation law here is a real bug, not a race
        // window.  `cfg!` (not `#[cfg]`) so release builds still typecheck
        // the seam without unused-variable warnings; the branch folds away.
        if cfg!(debug_assertions) {
            if let Some(check) = invariants.as_ref() {
                if let Err(e) = check() {
                    panic!("tick-boundary invariant violation: {e}");
                }
            }
            if let Err(e) = sessions.validate_gauges() {
                panic!("tick-boundary invariant violation: {e}");
            }
        }
    }
}

fn sweep_done(
    active: &mut Vec<SideAgent>,
    results: &mpsc::Sender<SideOutcome>,
    sessions: &SessionTable,
    gauges: &Gauges,
) {
    let mut i = 0;
    while i < active.len() {
        if active[i].is_done() {
            let agent = active.swap_remove(i);
            deliver(results, sessions, gauges, agent.into_outcome());
        } else {
            i += 1;
        }
    }
}

/// Deterministic host-only stand-ins for the fused executor, shared by the
/// equivalence proptest below and `benches/continuous_batch.rs` — ONE home
/// for the op-accounting rules the CI thresholds assert against, so the
/// bench can never drift from the semantics the tests pin.  Hidden: not
/// part of the serving API.
#[doc(hidden)]
pub mod testing {
    use super::*;
    use crate::runtime::ModelConfig;
    use crate::util::rng::XorShift;

    /// Deterministic per-item decode stub: depends ONLY on
    /// `(token, pos, view len)`, so a step's result is identical whether it
    /// ran fused or sequential — exactly the property the real engine's
    /// batch==single numerics tests establish on-device.
    pub fn stub_raw(cfg: &ModelConfig, token: i32, pos: i32, len: usize) -> RawDecode {
        let row = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        let seed = 0x57E9_0000_0000_0000
            ^ ((token as u64) << 40)
            ^ ((pos as u64) << 20)
            ^ len as u64;
        let mut rng = XorShift::new(seed);
        RawDecode {
            logits: (0..cfg.vocab_size).map(|_| rng.range_f32(-4.0, 4.0)).collect(),
            hidden: (0..cfg.d_model).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            k_new: (0..row).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            v_new: (0..row).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        }
    }

    /// Host-only fused executor mirroring [`crate::model::Engine::decode_fused`]'s
    /// op accounting: one batch op carries every fusable main plus the
    /// sides, and each unfusable main pays its own op ahead of it (a lone
    /// main with no sides is one single-decode op either way).
    pub fn stub_exec(cfg: ModelConfig, side_ctx: usize, batch_width: usize) -> FusedExec {
        Arc::new(move |mains, sides, fuse_main| {
            if mains.is_empty() && sides.is_empty() {
                anyhow::bail!("empty tick");
            }
            let main_out: Vec<std::result::Result<RawDecode, String>> = mains
                .iter()
                .map(|m| Ok(stub_raw(&cfg, m.req.token, m.req.pos, m.req.paged.len)))
                .collect();
            let side_out: Vec<RawDecode> = sides
                .iter()
                .map(|s| stub_raw(&cfg, s.token, s.pos, s.paged.len))
                .collect();
            let fused = mains
                .iter()
                .filter(|m| fuse_main && m.req.paged.len + 1 <= side_ctx)
                .count();
            if fused + sides.len() > batch_width {
                anyhow::bail!(
                    "stub_exec: {fused} fused mains + {} sides exceed width {batch_width}",
                    sides.len()
                );
            }
            let own = (mains.len() - fused) as u64;
            let batched = fused + sides.len();
            let device_ops = own + u64::from(batched > 0);
            Ok(FusedOut {
                mains: main_out,
                sides: side_out,
                side_error: None,
                device_ops,
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::testing::{stub_exec, stub_raw};
    use super::*;
    use crate::cortex::agent::AgentCache;
    use crate::cortex::router::AgentRole;
    use crate::model::{ChunkedPrefill, KvPool, KvPoolConfig};
    use crate::runtime::ModelConfig;
    use crate::text::{SamplerConfig, Tokenizer};
    use crate::util::proptest::check;
    use std::sync::atomic::AtomicBool;
    use std::time::Instant;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 16,
            vocab_size: 260,
            head_dim: 4,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn task(id: u64, payload: &str) -> SideTask {
        session_task(id, 0, payload)
    }

    fn session_task(id: u64, session: u64, payload: &str) -> SideTask {
        SideTask {
            id,
            session,
            role: AgentRole::Verify,
            payload: payload.into(),
            main_pos: 0,
            spawned_at: Instant::now(),
        }
    }

    fn sampler_cfg(seed: u64) -> SamplerConfig {
        SamplerConfig {
            temperature: 0.8,
            top_k: 20,
            top_p: 0.9,
            repetition_penalty: 1.1,
            repetition_window: 16,
            seed,
        }
    }

    /// Spawner over bare pool caches: prompt ids derived from the payload,
    /// exactly what the sequential reference reconstructs per task.
    fn bare_spawner(
        pool: Arc<KvPool>,
        side_ctx: usize,
        gen_budget: usize,
        seed: u64,
    ) -> AgentSpawner {
        Arc::new(move |t: SideTask| {
            let prompt_ids = Tokenizer::new().encode(&t.payload, false);
            SideAgent::from_parts(
                t,
                AgentCache::Bare(pool.new_cache(side_ctx)),
                0,
                7,
                prompt_ids,
                gen_budget,
                sampler_cfg(seed),
            )
        })
    }

    /// Run one agent to completion against the per-item stub, sequentially
    /// (one device op per step) — the bit-identical reference.
    fn run_sequential(cfg: &ModelConfig, agent: &mut SideAgent) -> u64 {
        let mut ops = 0u64;
        while let Some((token, pos)) = agent.next_request() {
            let len = agent.paged().len;
            agent.feed(stub_raw(cfg, token, pos, len));
            ops += 1;
        }
        ops
    }

    fn assert_outcomes_match(got: &SideOutcome, want: &SideOutcome) {
        assert_eq!(got.task.id, want.task.id);
        assert_eq!(got.state, want.state, "task {}", want.task.id);
        assert_eq!(got.text, want.text, "task {}", want.task.id);
        assert_eq!(got.tokens, want.tokens, "task {}", want.task.id);
        assert_eq!(got.hidden, want.hidden, "task {}", want.task.id);
        assert_eq!(got.steps, want.steps, "task {}", want.task.id);
        assert_eq!(got.error, want.error, "task {}", want.task.id);
    }

    #[test]
    fn completes_tasks_and_fuses_ticks() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let side_ctx = 64;
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 4,
                side_ctx: 64,
                max_active: 4,
                max_parked: 16,
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), side_ctx, 4),
                bare_spawner(pool, side_ctx, 8, 3),
            ),
        );
        for i in 0..6u64 {
            assert!(sched.submit(task(i, "check the cache")));
        }
        assert!(sched.drain(Duration::from_secs(5)), "tasks never finished");
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 6);
        let st = sched.stats();
        assert_eq!(st.completed, 6);
        assert!(st.side_steps > 0);
        // continuous batching must beat one-op-per-token
        assert!(
            st.device_ops < st.side_steps,
            "no fusion happened: {} ops for {} steps",
            st.device_ops,
            st.side_steps
        );
        sched.shutdown();
    }

    #[test]
    fn park_queue_backpressure_rejects_and_resumes_fifo() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let gate = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 2,
                side_ctx: 64,
                max_active: 1,
                max_parked: 2,
                ..StepConfig::default()
            },
            StepSeams {
                admit: Arc::new(move || g.load(Ordering::SeqCst)),
                ..StepSeams::new(stub_exec(cfg.clone(), 64, 2), bare_spawner(pool, 64, 4, 1))
            },
        );
        // Gate closed: everything parks; the 4th submit exceeds
        // max_active + max_parked and is rejected.
        assert!(sched.submit(task(1, "a")));
        assert!(sched.submit(task(2, "b")));
        assert!(sched.submit(task(3, "c")));
        assert!(!sched.submit(task(4, "d")), "park queue must backpressure");
        assert_eq!(sched.stats().rejected_capacity, 1);
        // Nothing admitted while the capacity gate is closed.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(sched.stats().admitted, 0);
        assert!(sched.stats().parked >= 2, "tasks should be parked");
        // Open the gate: all three run and finish, FIFO.
        gate.store(true, Ordering::SeqCst);
        assert!(sched.drain(Duration::from_secs(5)), "parked tasks never resumed");
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 3);
        assert_eq!(
            outcomes.iter().map(|o| o.task.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "admission must resume FIFO (max_active=1 serializes completion)"
        );
        sched.shutdown();
    }

    #[test]
    fn shutdown_fails_pending_work_but_delivers_outcomes() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 2,
                side_ctx: 64,
                max_active: 1,
                max_parked: 8,
                ..StepConfig::default()
            },
            StepSeams {
                admit: Arc::new(|| false), // never admit: tasks stay parked
                ..StepSeams::new(stub_exec(cfg.clone(), 64, 2), bare_spawner(pool, 64, 4, 1))
            },
        );
        assert!(sched.submit(task(1, "x")));
        assert!(sched.submit(task(2, "y")));
        sched.shutdown();
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 2, "parked tasks must surface on shutdown");
        for o in &outcomes {
            assert_eq!(o.state, SideState::Failed);
            assert!(o.error.as_deref().unwrap().contains("shut down"));
        }
        // post-shutdown requests error out instead of hanging
        let mut kv = KvPool::new(&tiny_cfg(), KvPoolConfig::default()).new_cache(64);
        assert!(sched.main_step(65, 0, &mut kv).is_err());
        assert!(!sched.submit(task(3, "z")));
    }

    /// A `side_error` tick (the engine's unfused 2-op path: main op
    /// succeeded, side batch failed) must fail ONLY the side lanes that
    /// were in the tick — and the scheduler keeps serving afterwards.
    #[test]
    fn side_error_fails_only_that_ticks_lanes() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let poisoned = Arc::new(AtomicBool::new(true));
        let exec: FusedExec = {
            let cfg = cfg.clone();
            let poisoned = poisoned.clone();
            Arc::new(move |mains: &[MainLane], sides: &[FusedReq], _fuse: bool| {
                let main_out: Vec<std::result::Result<RawDecode, String>> = mains
                    .iter()
                    .map(|m| Ok(stub_raw(&cfg, m.req.token, m.req.pos, m.req.paged.len)))
                    .collect();
                if poisoned.load(Ordering::SeqCst) && !sides.is_empty() {
                    return Ok(FusedOut {
                        mains: main_out,
                        sides: Vec::new(),
                        side_error: Some("injected side fault".into()),
                        device_ops: 2,
                    });
                }
                let side_out = sides
                    .iter()
                    .map(|s| stub_raw(&cfg, s.token, s.pos, s.paged.len))
                    .collect();
                Ok(FusedOut {
                    mains: main_out,
                    sides: side_out,
                    side_error: None,
                    device_ops: 1,
                })
            })
        };
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 4,
                side_ctx: 64,
                max_active: 4,
                max_parked: 8,
                ..StepConfig::default()
            },
            StepSeams::new(exec, bare_spawner(pool.clone(), 64, 4, 9)),
        );
        // Both agents land in a poisoned tick: Failed, with the side-batch
        // message — while a concurrent main step still succeeds.
        assert!(sched.submit(task(1, "alpha")));
        assert!(sched.submit(task(2, "beta")));
        let mut main_kv = pool.new_cache(128);
        sched.main_step(5, 0, &mut main_kv).expect("main must survive a side fault");
        assert!(sched.drain(Duration::from_secs(5)));
        let got = sched.poll_results();
        assert_eq!(got.len(), 2);
        for o in &got {
            assert_eq!(o.state, SideState::Failed);
            assert!(o.error.as_deref().unwrap().contains("side batch failed"), "{:?}", o.error);
        }
        // Heal the executor: the scheduler keeps serving new tasks.
        poisoned.store(false, Ordering::SeqCst);
        assert!(sched.submit(task(3, "gamma")));
        assert!(sched.drain(Duration::from_secs(5)));
        let ok = sched.poll_results();
        assert_eq!(ok.len(), 1);
        assert!(ok[0].error.is_none(), "{:?}", ok[0].error);
        sched.shutdown();
    }

    /// The acceptance-criteria proptest: fused scheduling is bit-identical
    /// to the sequential per-agent path across random admit/park/finish
    /// interleavings (random widths, budgets, capacity-gate flaps and
    /// interleaved main steps).
    #[test]
    fn fused_equals_sequential_across_interleavings() {
        check("step scheduler ≡ sequential decode", 40, |g| {
            let cfg = tiny_cfg();
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig { block_tokens: 8, ..Default::default() },
            );
            let side_ctx = 64;
            let batch_width = g.usize_in(1..6);
            let max_active = g.usize_in(1..6);
            let fuse_main = g.bool();
            let n_tasks = g.usize_in(1..9);
            let gen_budget = g.usize_in(1..10);
            let seed = g.usize_in(1..1000) as u64;
            let main_steps = g.usize_in(0..12);

            // A capacity gate that flaps (deterministically) to exercise
            // parking + FIFO resume; numerics must be unaffected.
            let flap = Arc::new(AtomicU64::new(0));
            let admit: AdmitGate = {
                let flap = flap.clone();
                Arc::new(move || flap.fetch_add(1, Ordering::Relaxed) % 3 != 1)
            };
            let sched = StepScheduler::new(
                StepConfig {
                    batch_width,
                    side_ctx,
                    max_active,
                    max_parked: n_tasks + 1,
                    fuse_main,
                    ..StepConfig::default()
                },
                StepSeams {
                    admit,
                    ..StepSeams::new(
                        stub_exec(cfg.clone(), side_ctx, batch_width),
                        bare_spawner(pool.clone(), side_ctx, gen_budget, seed),
                    )
                },
            );

            let payloads: Vec<String> =
                (0..n_tasks).map(|i| format!("task {i} {}", g.usize_in(0..50))).collect();
            // Interleave submissions with main steps against a live cache.
            let mut main_kv = pool.new_cache(128);
            let mut twin_kv = pool.new_cache(128);
            let mut main_outs = Vec::new();
            let mut submitted = 0usize;
            for step in 0..main_steps.max(n_tasks) {
                if submitted < n_tasks {
                    crate::prop_assert!(
                        sched.submit(task(submitted as u64 + 1, &payloads[submitted])),
                        "submit {submitted} rejected below the bound"
                    );
                    submitted += 1;
                }
                if step < main_steps {
                    let tok = (step % 200) as i32;
                    let pos = main_kv.len() as i32;
                    let out = sched
                        .main_step(tok, pos, &mut main_kv)
                        .map_err(|e| format!("main step failed: {e:#}"))?;
                    main_outs.push(out);
                }
            }
            crate::prop_assert!(
                sched.drain(Duration::from_secs(10)),
                "scheduler never drained (width {batch_width}, active {max_active})"
            );
            let mut got = sched.poll_results();
            got.sort_by_key(|o| o.task.id);
            crate::prop_assert!(got.len() == n_tasks, "lost outcomes: {} of {n_tasks}", got.len());
            let st = sched.stats();
            crate::prop_assert!(st.main_deferred == 0, "single-main runs must never defer mains");
            sched.check_invariants()?;
            pool.check_invariants()?;
            sched.shutdown();

            // Sequential reference: identical parts, one op per step.
            for (i, payload) in payloads.iter().enumerate() {
                let t = task(i as u64 + 1, payload);
                let prompt_ids = Tokenizer::new().encode(payload, false);
                let mut reference = SideAgent::from_parts(
                    t,
                    AgentCache::Bare(pool.new_cache(side_ctx)),
                    0,
                    7,
                    prompt_ids,
                    gen_budget,
                    sampler_cfg(seed),
                );
                run_sequential(&cfg, &mut reference);
                assert_outcomes_match(&got[i], &reference.into_outcome());
            }
            // Main chain: bit-identical to the direct per-step stub path.
            for (step, out) in main_outs.iter().enumerate() {
                let tok = (step % 200) as i32;
                let pos = twin_kv.len() as i32;
                let want = stub_raw(&cfg, tok, pos, twin_kv.len());
                twin_kv
                    .append_row(&want.k_new, &want.v_new)
                    .map_err(|e| format!("twin append: {e:#}"))?;
                crate::prop_assert!(out.logits == want.logits, "main logits diverged at step {step}");
                crate::prop_assert!(out.hidden == want.hidden, "main hidden diverged at step {step}");
            }
            Ok(())
        });
    }

    #[test]
    fn sessions_park_fifo_and_admit_as_slots_free() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 2,
                side_ctx: 64,
                max_sessions: 1,
                max_parked_sessions: 4,
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 2),
                bare_spawner(pool.clone(), 64, 4, 1),
            ),
        );
        let first = sched.open_session().expect("first session admits");
        let (tx, rx) = mpsc::channel();
        let waiter = {
            let sched = sched.clone();
            std::thread::spawn(move || {
                let p = sched.open_session().expect("parked session eventually admits");
                tx.send(p.id()).unwrap();
            })
        };
        let deadline = Instant::now() + Duration::from_secs(5);
        while sched.session_stats().parked == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        let ss = sched.session_stats();
        assert_eq!(ss.parked, 1, "second session must park behind the slot");
        assert_eq!(ss.admitted, 1);
        assert!(rx.try_recv().is_err(), "parked session admitted early");
        // Freeing the slot admits the parked session.
        let first_id = first.id();
        drop(first);
        let second_id = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("parked session admitted after the slot freed");
        assert!(second_id > first_id, "sessions admit in arrival order");
        waiter.join().unwrap();
        let ss = sched.session_stats();
        assert_eq!(ss.requested, 2);
        assert_eq!(ss.admitted, 2);
        assert_eq!(ss.rejected, 0);
        assert_eq!(ss.completed, 2);
        assert_eq!(ss.active, 0);
        assert_eq!(ss.parked, 0);
        assert_eq!(ss.parked_peak, 1);
        sched.shutdown();
    }

    #[test]
    fn session_queue_backpressure_and_shutdown_reject_cleanly() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                max_sessions: 1,
                max_parked_sessions: 0,
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 1),
                bare_spawner(pool.clone(), 64, 4, 1),
            ),
        );
        let held = sched.open_session().expect("slot free");
        // No parking allowed: the second request sheds immediately.
        assert_eq!(sched.open_session().unwrap_err(), SessionDenied::QueueFull);
        assert_eq!(sched.session_stats().rejected, 1);
        drop(held);
        drop(sched.open_session().expect("slot freed"));
        sched.shutdown();

        // A parked opener wakes with ShuttingDown when the scheduler stops.
        let sched2 = StepScheduler::new(
            StepConfig {
                max_sessions: 1,
                max_parked_sessions: 4,
                ..StepConfig::default()
            },
            StepSeams::new(stub_exec(cfg.clone(), 64, 1), bare_spawner(pool, 64, 4, 1)),
        );
        let hold = sched2.open_session().unwrap();
        let waiter = {
            let s = sched2.clone();
            std::thread::spawn(move || s.open_session().unwrap_err())
        };
        let deadline = Instant::now() + Duration::from_secs(5);
        while sched2.session_stats().parked == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(sched2.session_stats().parked, 1);
        sched2.shutdown();
        assert_eq!(waiter.join().unwrap(), SessionDenied::ShuttingDown);
        assert_eq!(
            sched2.open_session().unwrap_err(),
            SessionDenied::ShuttingDown,
            "post-shutdown opens must refuse, not hang"
        );
        drop(hold);
    }

    /// The tentpole property at scheduler level: two concurrent sessions'
    /// main steps share fused ticks — neither serializes behind the other
    /// (no cross-session head-of-line blocking) and neither is ever
    /// deferred behind side work.
    #[test]
    fn concurrent_sessions_fuse_into_shared_ticks() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 4,
                side_ctx: 64,
                max_sessions: 4,
                max_parked_sessions: 8,
                main_gather: Duration::from_millis(2),
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 4),
                bare_spawner(pool.clone(), 64, 4, 5),
            ),
        );
        const STEPS: usize = 32;
        std::thread::scope(|scope| {
            for s in 0..2usize {
                let sched = sched.clone();
                let pool = pool.clone();
                scope.spawn(move || {
                    let _permit = sched.open_session().expect("session admits");
                    let mut kv = pool.new_cache(128);
                    for step in 0..STEPS {
                        let tok = ((s * 31 + step) % 200) as i32;
                        sched
                            .main_step(tok, kv.len() as i32, &mut kv)
                            .expect("main step");
                    }
                });
            }
        });
        let st = sched.stats();
        assert_eq!(st.main_steps, (2 * STEPS) as u64);
        assert_eq!(st.main_deferred, 0, "fusable mains share a tick, never defer");
        assert!(
            st.device_ops < st.main_steps,
            "{} ops for {} steps: sessions never fused",
            st.device_ops,
            st.main_steps
        );
        let ss = sched.session_stats();
        assert!(
            ss.occupancy > 1.0,
            "occupancy {} must exceed one stream per tick",
            ss.occupancy
        );
        assert_eq!(ss.admitted, 2);
        assert_eq!(ss.completed, 2);
        sched.shutdown();
    }

    /// Main-saturated session tables must not starve side agents: with as
    /// many pending fusable mains as batch lanes every tick, one lane
    /// stays reserved for live side work, so the side outcome lands while
    /// the mains are still flowing — not only after they drain.
    #[test]
    fn saturated_mains_leave_a_lane_for_side_agents() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 2,
                side_ctx: 64,
                max_sessions: 3,
                max_parked_sessions: 4,
                main_gather: Duration::from_millis(1),
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 2),
                bare_spawner(pool.clone(), 64, 4, 11),
            ),
        );
        let a = sched.open_session().unwrap();
        assert!(sched.submit(session_task(1, a.id(), "starved?")));
        const DRIVER_STEPS: usize = 60;
        let done_steps = Arc::new(AtomicU64::new(0));
        std::thread::scope(|scope| {
            // Two sessions keep a fusable main pending essentially every
            // tick — enough to fill both lanes without the reservation.
            for s in 0..2usize {
                let sched = sched.clone();
                let pool = pool.clone();
                let done = done_steps.clone();
                scope.spawn(move || {
                    let _p = sched.open_session().expect("driver session admits");
                    let mut kv = pool.new_cache(128);
                    for step in 0..DRIVER_STEPS {
                        let tok = ((s * 13 + step) % 200) as i32;
                        sched
                            .main_step(tok, kv.len() as i32, &mut kv)
                            .expect("main step");
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
            let got = sched
                .wait_session_result(a.id(), Duration::from_secs(10))
                .expect("side agent starved behind saturating mains");
            assert!(got.error.is_none(), "{:?}", got.error);
            let mains_done = done_steps.load(Ordering::SeqCst);
            assert!(
                (mains_done as usize) < 2 * DRIVER_STEPS - 10,
                "side outcome only arrived after the mains drained \
                 (starvation): {mains_done} of {} main steps already done",
                2 * DRIVER_STEPS
            );
        });
        drop(a);
        sched.shutdown();
    }

    /// Side outcomes route to the session that spawned them — never to
    /// another session's merge loop or the global channel — and a
    /// disconnected session's undelivered outcomes are dropped, not
    /// leaked.
    #[test]
    fn session_outcome_routing_is_isolated() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 4,
                side_ctx: 64,
                max_sessions: 4,
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 4),
                bare_spawner(pool.clone(), 64, 4, 3),
            ),
        );
        let a = sched.open_session().unwrap();
        let b = sched.open_session().unwrap();
        assert!(sched.submit(session_task(1, a.id(), "alpha")));
        assert!(sched.submit(session_task(2, b.id(), "beta")));
        let got_a = sched
            .wait_session_result(a.id(), Duration::from_secs(5))
            .expect("a's outcome");
        let got_b = sched
            .wait_session_result(b.id(), Duration::from_secs(5))
            .expect("b's outcome");
        assert_eq!(got_a.task.id, 1);
        assert_eq!(got_b.task.id, 2);
        assert!(sched.poll_session_results(a.id()).is_empty());
        assert!(
            sched.poll_results().is_empty(),
            "session outcomes must not leak to the global channel"
        );
        // Disconnect: the session closes before its outcome lands.
        let c = sched.open_session().unwrap();
        let c_id = c.id();
        assert!(sched.submit(session_task(3, c_id, "gamma")));
        drop(c);
        assert!(
            sched.drain(Duration::from_secs(5)),
            "the orphaned agent still runs to completion"
        );
        assert!(sched.poll_results().is_empty());
        assert!(sched.poll_session_results(c_id).is_empty());
        assert!(
            sched
                .wait_session_result(c_id, Duration::from_millis(10))
                .is_none(),
            "a closed session's queue is gone"
        );
        drop((a, b));
        sched.shutdown();
    }

    /// The acceptance-criteria proptest: S concurrent sessions through the
    /// fused tick loop are bit-identical to the same S episodes run
    /// sequentially, across random widths, session caps (forcing FIFO
    /// parking), gather windows, side-task loads and mid-stream
    /// disconnects.
    #[test]
    fn multi_session_fused_equals_sequential_episodes() {
        struct Plan {
            cut: usize,
            disconnect: bool,
            sides: Vec<String>,
        }
        check("S fused sessions ≡ S sequential episodes", 20, |g| {
            let cfg = tiny_cfg();
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig { block_tokens: 8, ..Default::default() },
            );
            let side_ctx = 64;
            let batch_width = g.usize_in(1..6);
            let n_sessions = g.usize_in(1..5);
            let max_sessions = g.usize_in(1..n_sessions + 1);
            let gen_budget = g.usize_in(1..6);
            let seed = g.usize_in(1..1000) as u64;
            let fuse_main = g.bool();
            let gather = Duration::from_micros(g.usize_in(0..400) as u64);
            let sched = StepScheduler::new(
                StepConfig {
                    batch_width,
                    side_ctx,
                    max_active: 4,
                    max_parked: 64,
                    fuse_main,
                    max_sessions,
                    max_parked_sessions: n_sessions + 1,
                    main_gather: gather,
                    prefill_budget: 2,
                },
                StepSeams::new(
                    stub_exec(cfg.clone(), side_ctx, batch_width),
                    bare_spawner(pool.clone(), side_ctx, gen_budget, seed),
                ),
            );
            let plans: Vec<Plan> = (0..n_sessions)
                .map(|_| {
                    let steps = g.usize_in(1..10);
                    let disconnect = g.bool() && g.bool(); // ~25%
                    let cut = if disconnect { g.usize_in(0..steps) } else { steps };
                    let sides = (0..g.usize_in(0..3))
                        .map(|j| format!("probe {j} {}", g.usize_in(0..50)))
                        .collect();
                    Plan { cut, disconnect, sides }
                })
                .collect();
            type SessRun = std::result::Result<(Vec<MainStepOut>, Vec<SideOutcome>), String>;
            let runs: Vec<SessRun> = std::thread::scope(|scope| {
                let handles: Vec<_> = plans
                    .iter()
                    .enumerate()
                    .map(|(s, plan)| {
                        let sched = sched.clone();
                        let pool = pool.clone();
                        scope.spawn(move || -> SessRun {
                            let permit =
                                sched.open_session().map_err(|e| format!("open: {e}"))?;
                            let sid = permit.id();
                            for (j, payload) in plan.sides.iter().enumerate() {
                                let t = session_task((s * 100 + j + 1) as u64, sid, payload);
                                if !sched.submit(t) {
                                    return Err(format!("session {s}: side submit rejected"));
                                }
                            }
                            let mut kv = pool.new_cache(128);
                            let mut outs = Vec::new();
                            for step in 0..plan.cut {
                                let tok = ((s * 31 + step * 7) % 200) as i32;
                                let out = sched
                                    .main_step(tok, kv.len() as i32, &mut kv)
                                    .map_err(|e| format!("session {s} step {step}: {e:#}"))?;
                                outs.push(out);
                            }
                            let mut got = Vec::new();
                            if !plan.disconnect {
                                let deadline = Instant::now() + Duration::from_secs(10);
                                while got.len() < plan.sides.len()
                                    && Instant::now() < deadline
                                {
                                    if let Some(o) = sched
                                        .wait_session_result(sid, Duration::from_millis(20))
                                    {
                                        got.push(o);
                                    }
                                }
                            }
                            drop(permit);
                            Ok((outs, got))
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("session thread"))
                    .collect()
            });
            sched.drain(Duration::from_secs(10));
            let ss = sched.session_stats();
            sched.check_invariants()?;
            pool.check_invariants()?;
            sched.shutdown();
            for (s, (plan, run)) in plans.iter().zip(&runs).enumerate() {
                let (outs, sides) = match run {
                    Ok(r) => r,
                    Err(e) => return Err(e.clone()),
                };
                // Main chain ≡ the direct per-step stub (pos == len == step
                // on a private main cache).
                crate::prop_assert!(outs.len() == plan.cut, "session {s} lost steps");
                for (step, out) in outs.iter().enumerate() {
                    let tok = ((s * 31 + step * 7) % 200) as i32;
                    let want = stub_raw(&cfg, tok, step as i32, step);
                    crate::prop_assert!(
                        out.logits == want.logits,
                        "session {s} logits diverged at step {step}"
                    );
                    crate::prop_assert!(
                        out.hidden == want.hidden,
                        "session {s} hidden diverged at step {step}"
                    );
                }
                // Side outcomes ≡ the sequential per-agent reference.
                if !plan.disconnect {
                    crate::prop_assert!(
                        sides.len() == plan.sides.len(),
                        "session {s}: {} of {} side outcomes",
                        sides.len(),
                        plan.sides.len()
                    );
                    let mut sorted: Vec<&SideOutcome> = sides.iter().collect();
                    sorted.sort_by_key(|o| o.task.id);
                    for (j, payload) in plan.sides.iter().enumerate() {
                        let id = (s * 100 + j + 1) as u64;
                        let prompt_ids = Tokenizer::new().encode(payload, false);
                        let mut reference = SideAgent::from_parts(
                            session_task(id, 0, payload),
                            AgentCache::Bare(pool.new_cache(side_ctx)),
                            0,
                            7,
                            prompt_ids,
                            gen_budget,
                            sampler_cfg(seed),
                        );
                        run_sequential(&cfg, &mut reference);
                        assert_outcomes_match(sorted[j], &reference.into_outcome());
                    }
                }
            }
            // Gauge reconciliation: every request accounted for exactly once.
            crate::prop_assert!(ss.requested == n_sessions as u64, "requested {ss:?}");
            crate::prop_assert!(
                ss.admitted + ss.rejected == ss.requested,
                "admission must account every request: {ss:?}"
            );
            crate::prop_assert!(ss.rejected == 0, "queue was sized to fit: {ss:?}");
            crate::prop_assert!(ss.completed == ss.admitted, "every permit dropped: {ss:?}");
            crate::prop_assert!(ss.active == 0 && ss.parked == 0, "{ss:?}");
            Ok(())
        });
    }

    /// The tentpole's mid-prefill sharing path end to end: while session A
    /// is still prefilling chunk-by-chunk, an identical prompt B admits,
    /// warm-attaches the blocks A has already published, and then adopts
    /// A's *next* block from the registry mid-prefill — B teacher-forces
    /// only the final token (the one coverage never includes) and its
    /// first-sample logits are bit-identical to A's.
    #[test]
    fn interleaved_identical_prompts_hit_the_registry_mid_prefill() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig {
                batch_width: 2,
                side_ctx: 64,
                max_sessions: 4,
                prefill_budget: 1,
                ..StepConfig::default()
            },
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 2),
                bare_spawner(pool.clone(), 64, 4, 1),
            ),
        );
        // 33 tokens over 8-token blocks: coverage spans rows 0..32 (four
        // blocks); row 32 always decodes live for the first sample.
        let toks: Vec<i32> = (0..33).map(|i| (i % 200) as i32).collect();
        let _a = sched.open_session().unwrap();
        let mut kv_a = pool.new_cache(64);
        let mut cp_a = ChunkedPrefill::begin(&toks, &mut kv_a).unwrap();
        assert_eq!(cp_a.adopted_rows(), 0, "registry starts cold");
        for _ in 0..24 {
            let (tok, pos) = cp_a.next_lane(&mut kv_a).expect("A has rows left");
            sched.prefill_step(tok, pos, &mut kv_a).unwrap();
            cp_a.advance(&mut kv_a);
        }
        // B admits mid-prefill: A's three completed blocks are already in
        // the registry, so B warm-starts at row 24 instead of running a
        // duplicate cold prefill.
        let _b = sched.open_session().unwrap();
        let mut kv_b = pool.new_cache(64);
        let mut cp_b = ChunkedPrefill::begin(&toks, &mut kv_b).unwrap();
        assert_eq!(cp_b.begin_cached_rows(), 24, "B rides A's published blocks");
        // A finishes, publishing its fourth block at the row-32 boundary.
        let mut last_a = None;
        while let Some((tok, pos)) = cp_a.next_lane(&mut kv_a) {
            last_a = Some(sched.prefill_step(tok, pos, &mut kv_a).unwrap());
            cp_a.advance(&mut kv_a);
        }
        assert!(cp_a.is_done());
        // B's next lane probe adopts that block from the registry: eight
        // rows of teacher-forcing skipped, only the final token runs live.
        let (tok, pos) = cp_b.next_lane(&mut kv_b).expect("final token decodes live");
        assert_eq!((tok, pos), (toks[32], 32));
        assert_eq!(cp_b.mid_hit_rows(), 8, "B adopted A's mid-prefill block");
        let out_b = sched.prefill_step(tok, pos, &mut kv_b).unwrap();
        cp_b.advance(&mut kv_b);
        assert!(cp_b.is_done());
        let want = stub_raw(&cfg, toks[32], 32, 32);
        assert_eq!(out_b.logits, want.logits, "chunked+adopted ≡ monolithic");
        assert_eq!(last_a.unwrap().logits, want.logits, "A and B converge");
        let st = sched.stats();
        assert_eq!(st.prefill_steps, 34, "A teacher-forced 33 rows, B one");
        assert!(st.prefill_ticks >= 1);
        assert_eq!(st.prefill_deferred, 0, "lone prefill stream never defers");
        assert_eq!(pool.stats().prefix_mid_hits, 1, "one mid-prefill chain hit");
        sched.shutdown();
    }

    /// Satellite: a prompt prefilled in scheduler-interleaved chunks is
    /// bit-identical to the monolithic prefill of the same prompt — across
    /// random per-tick budgets, warm-coverage boundaries (a prior identical
    /// prompt left blocks in the registry), concurrent decode sessions and
    /// mid-prefill abandonment — and the concurrent decode chains are
    /// untouched by the interleave.
    #[test]
    fn chunked_prefill_equals_monolithic_across_interleavings() {
        check("chunked prefill ≡ monolithic", 16, |g| {
            let cfg = tiny_cfg();
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig { block_tokens: 8, ..Default::default() },
            );
            let side_ctx = 64;
            let batch_width = g.usize_in(1..6);
            let prefill_budget = g.usize_in(1..4);
            let fuse_main = g.bool();
            let n_len = g.usize_in(1..50);
            let n_decoders = g.usize_in(0..3);
            let decode_steps = g.usize_in(1..8);
            let pre_rows = if g.bool() { g.usize_in(0..n_len + 1) } else { 0 };
            let abandon = g.bool() && g.bool(); // ~25%: drop mid-prefill
            let cut = if abandon { g.usize_in(0..n_len) } else { n_len };
            let sched = StepScheduler::new(
                StepConfig {
                    batch_width,
                    side_ctx,
                    max_sessions: n_decoders + 1,
                    max_parked_sessions: 4,
                    main_gather: Duration::from_micros(g.usize_in(0..300) as u64),
                    fuse_main,
                    prefill_budget,
                    ..StepConfig::default()
                },
                StepSeams::new(
                    stub_exec(cfg.clone(), side_ctx, batch_width),
                    bare_spawner(pool.clone(), side_ctx, 3, 1),
                ),
            );
            let toks: Vec<i32> = (0..n_len).map(|i| ((i * 7 + 3) % 200) as i32).collect();
            // Optionally a prior identical prompt leaves `pre_rows`-worth of
            // complete blocks in the registry (held live for the whole run),
            // so this run's begin() lands on a random coverage boundary.
            let mut warm = pool.new_cache(64);
            if pre_rows > 0 {
                let mut cp = ChunkedPrefill::begin(&toks, &mut warm)
                    .map_err(|e| format!("warm begin: {e:#}"))?;
                for _ in 0..pre_rows {
                    let Some((tok, pos)) = cp.next_lane(&mut warm) else { break };
                    let raw = stub_raw(&cfg, tok, pos, warm.len());
                    warm.append_row(&raw.k_new, &raw.v_new)
                        .map_err(|e| format!("warm append: {e:#}"))?;
                    cp.advance(&mut warm);
                }
            }
            type PrefillRun =
                std::result::Result<(usize, Vec<(usize, MainStepOut)>, bool), String>;
            type DecodeRun = std::result::Result<Vec<MainStepOut>, String>;
            let (prefill_run, decode_runs) = std::thread::scope(|scope| {
                let prefill_handle = {
                    let sched = sched.clone();
                    let pool = pool.clone();
                    let toks = toks.clone();
                    scope.spawn(move || -> PrefillRun {
                        let _permit =
                            sched.open_session().map_err(|e| format!("open: {e}"))?;
                        let mut kv = pool.new_cache(64);
                        let mut cp = ChunkedPrefill::begin(&toks, &mut kv)
                            .map_err(|e| format!("begin: {e:#}"))?;
                        let mut steps = Vec::new();
                        while steps.len() < cut {
                            let Some((tok, pos)) = cp.next_lane(&mut kv) else { break };
                            let out = sched
                                .prefill_step(tok, pos, &mut kv)
                                .map_err(|e| format!("prefill step {pos}: {e:#}"))?;
                            cp.advance(&mut kv);
                            steps.push((pos as usize, out));
                        }
                        Ok((cp.adopted_rows(), steps, cp.is_done()))
                    })
                };
                let decode_handles: Vec<_> = (0..n_decoders)
                    .map(|s| {
                        let sched = sched.clone();
                        let pool = pool.clone();
                        scope.spawn(move || -> DecodeRun {
                            let _permit =
                                sched.open_session().map_err(|e| format!("open: {e}"))?;
                            let mut kv = pool.new_cache(64);
                            let mut outs = Vec::new();
                            for step in 0..decode_steps {
                                let tok = ((s * 31 + step * 7) % 200) as i32;
                                let out = sched
                                    .main_step(tok, kv.len() as i32, &mut kv)
                                    .map_err(|e| format!("decoder {s} step {step}: {e:#}"))?;
                                outs.push(out);
                            }
                            Ok(outs)
                        })
                    })
                    .collect();
                (
                    prefill_handle.join().expect("prefill thread"),
                    decode_handles
                        .into_iter()
                        .map(|h| h.join().expect("decoder thread"))
                        .collect::<Vec<_>>(),
                )
            });
            let (adopted, steps, done) = prefill_run?;
            // Every teacher-forced lane that ran is bit-identical to the
            // monolithic prefill's step at the same position (pos == view
            // len == i), independent of budget, boundary and interleaving.
            for (pos, out) in &steps {
                let want = stub_raw(&cfg, toks[*pos], *pos as i32, *pos);
                crate::prop_assert!(
                    out.logits == want.logits && out.hidden == want.hidden,
                    "chunked lane diverged from monolithic at row {pos}"
                );
            }
            if !abandon {
                crate::prop_assert!(done, "prefill must complete when not abandoned");
                // Adoption + live lanes partition the prompt exactly, and
                // the final lane is always live at the last position — its
                // output IS the monolithic first-sample result.
                crate::prop_assert!(
                    adopted + steps.len() == n_len,
                    "{adopted} adopted + {} live != {n_len}",
                    steps.len()
                );
                let (last_pos, _) = steps.last().expect("coverage stops before the end");
                crate::prop_assert!(*last_pos == n_len - 1, "last lane at {last_pos}");
            }
            let st = sched.stats();
            crate::prop_assert!(
                st.prefill_steps == steps.len() as u64,
                "every prefill lane accounted: {} != {}",
                st.prefill_steps,
                steps.len()
            );
            // Concurrent decode chains are untouched by the interleave.
            for (s, run) in decode_runs.iter().enumerate() {
                let outs = match run {
                    Ok(o) => o,
                    Err(e) => return Err(e.clone()),
                };
                for (step, out) in outs.iter().enumerate() {
                    let tok = ((s * 31 + step * 7) % 200) as i32;
                    let want = stub_raw(&cfg, tok, step as i32, step);
                    crate::prop_assert!(
                        out.logits == want.logits,
                        "decoder {s} diverged at step {step} during prefill"
                    );
                }
            }
            sched.check_invariants()?;
            pool.check_invariants()?;
            sched.shutdown();
            drop(warm);
            Ok(())
        });
    }

    /// Satellite: the sanitizer must name each violated session-gauge law.
    #[test]
    fn sanitizer_names_session_gauge_drift() {
        let cfg = tiny_cfg();
        let pool =
            KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig::default(),
            StepSeams::new(
                stub_exec(cfg.clone(), 64, 1),
                bare_spawner(pool.clone(), 64, 2, 1),
            ),
        );
        // A real open/close cycle first: the laws hold on honest gauges.
        let permit = sched.open_session().expect("admit");
        drop(permit);
        sched.check_invariants().expect("honest gauges reconcile");

        sched.corrupt_admitted_gauge();
        let err = sched.check_invariants().expect_err("seeded admitted drift");
        assert!(
            err.contains("session-admission-conservation"),
            "law not named: {err}"
        );
        // Undo, then break the other law in isolation.
        sched.sessions.state.lock().admitted -= 1;
        sched.corrupt_requested_gauge();
        let err = sched.check_invariants().expect_err("seeded requested drift");
        assert!(
            err.contains("session-request-conservation"),
            "law not named: {err}"
        );
        sched.sessions.state.lock().requested -= 1;
        sched.check_invariants().expect("restored gauges reconcile");
        sched.shutdown();
    }
}
