//! The step scheduler: iteration-level continuous batching for main and
//! side decode (the PR-4 tentpole).
//!
//! The pre-PR-4 topology gave the device a *serial* op stream: the main
//! agent issued one blocking decode op per token from the episode thread,
//! while side agents funnelled through the linger-based [`super::batcher`]
//! on their own worker threads.  `capacity.rs`'s utilization model showed
//! compute — not memory — had become the binding constraint on the paper's
//! ">1,000 agents" claim.  The fix is the serving classic (vLLM-style
//! continuous batching, at iteration granularity): one device-feeding loop
//! that, every tick,
//!
//! 1. collects the next-token work item from every runnable agent — the
//!    main agent's pending step plus one `(token, pos, block-table)` item
//!    per live side agent (side agents are *pollable state machines*
//!    ([`super::agent::SideAgent`]), not blocked threads),
//! 2. fuses them into one [`crate::model::Engine::decode_fused`] call over
//!    O(k) paged block tables (main rides lane 0 of the batch program at
//!    River priority while its context fits; afterwards it runs as its own
//!    River op *ahead of* the side batch — the main agent is never queued
//!    behind side work),
//! 3. fans results back: the main reply through its per-request completion
//!    channel, side rows fed straight into each agent's state machine.
//!
//! Admission is capacity-aware and continuous: new side tasks park in a
//! FIFO queue and are admitted only while the live-agent count is under
//! `max_active` AND the admission gate (pool occupancy, in production)
//! says a fresh side cache still fits; a finishing agent's slot is
//! refilled on the *very next tick*, not at batch boundaries.
//!
//! The scheduler is engine-agnostic behind three seams — the fused
//! executor, the agent spawner and the admission gate — so the
//! fused-vs-sequential equivalence proptest below and
//! `benches/continuous_batch.rs` drive the full admit/park/finish protocol
//! host-only.  All locks on the request path are poison-tolerant
//! ([`crate::util::sync`]): one panicking caller surfaces as its own
//! `Err`, it does not wedge every later request.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use super::agent::{SideAgent, SideOutcome, SideState, SideTask};
use crate::model::{FusedOut, FusedReq, KvCache, PagedKv, RawDecode};
use crate::util::sync::lock_unpoisoned;

/// The fused decode executor: `(main item, main cache capacity, side
/// items, fuse_main)` → one tick's results.  Production wraps
/// [`crate::model::Engine::decode_fused`]; tests and the
/// continuous-batching bench inject deterministic host-only stubs.
pub type FusedExec =
    Arc<dyn Fn(Option<&FusedReq>, usize, &[FusedReq], bool) -> Result<FusedOut> + Send + Sync>;

/// Builds a live [`SideAgent`] for an admitted task.  Production wraps
/// [`SideAgent::spawn`] (prism registration + synapse seeding); tests use
/// [`SideAgent::from_parts`] over bare pool caches.
pub type AgentSpawner = Arc<dyn Fn(SideTask) -> SideAgent + Send + Sync>;

/// Capacity gate consulted before each admission: `false` parks the task
/// (retried every tick).  Production checks pool occupancy — a fresh
/// side cache's worst-case blocks must still fit under `max_blocks`.
pub type AdmitGate = Arc<dyn Fn() -> bool + Send + Sync>;

/// Scheduler knobs (production values are derived from
/// [`super::CortexConfig`] and the engine capacities).
#[derive(Debug, Clone)]
pub struct StepConfig {
    /// Lanes of the compiled batch program (`caps.decode_batch`): the hard
    /// per-tick fusion width.
    pub batch_width: usize,
    /// Rows one batch lane can hold (`caps.side_ctx`).  Decides whether a
    /// pending main step can ride lane 0 (`len + 1 <= side_ctx`); a main
    /// that has outgrown a lane runs as its own op and reserves NO lane —
    /// sides keep the full width.
    pub side_ctx: usize,
    /// Max concurrently *decoding* side agents; beyond this, tasks park.
    pub max_active: usize,
    /// Max parked tasks beyond the active ones (submit backpressure).
    pub max_parked: usize,
    /// Ride the main step on lane 0 of the batch program while its context
    /// fits a side-capacity lane (one device op per tick).  Off = the main
    /// step always runs as its own River op ahead of the side batch.
    pub fuse_main: bool,
}

/// Result of one main-agent step routed through the scheduler.
#[derive(Debug)]
pub struct MainStepOut {
    pub logits: Vec<f32>,
    pub hidden: Vec<f32>,
}

/// Live scheduler statistics (the `/stats` `step` gauges).
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    /// Side tasks accepted by `submit`.
    pub submitted: u64,
    /// Side-task outcomes delivered to the results channel.
    pub completed: u64,
    /// Side tasks rejected at submit (park queue full).
    pub rejected_capacity: u64,
    /// Side agents currently decoding.
    pub active: usize,
    /// Side tasks currently parked awaiting admission.
    pub parked: usize,
    /// High-water parked count.
    pub parked_peak: usize,
    /// Parked tasks admitted to the active set.
    pub admitted: u64,
    /// Fused ticks executed.
    pub ticks: u64,
    /// Device ops those ticks actually issued.
    pub device_ops: u64,
    /// Main-agent steps served.
    pub main_steps: u64,
    /// Side-agent steps served.
    pub side_steps: u64,
    /// Ticks where the main step rode the side batch in one device op.
    pub fused_ticks: u64,
    /// Main steps that had to wait a tick behind *another main* (never
    /// behind side work; >0 only with concurrent episodes).
    pub main_deferred: u64,
}

impl StepStats {
    /// Device ops per generated token — the continuous-batching figure of
    /// merit: ~1.0 for the serial pre-PR-4 path, → 1/B as the population
    /// grows.
    pub fn ops_per_token(&self) -> f64 {
        let tokens = self.main_steps + self.side_steps;
        if tokens == 0 {
            0.0
        } else {
            self.device_ops as f64 / tokens as f64
        }
    }

    /// Mean decoded tokens per device op (the batch-occupancy gauge;
    /// inverse of [`StepStats::ops_per_token`]).
    pub fn batch_occupancy(&self) -> f64 {
        if self.device_ops == 0 {
            0.0
        } else {
            (self.main_steps + self.side_steps) as f64 / self.device_ops as f64
        }
    }
}

struct Gauges {
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
    admitted: AtomicU64,
    ticks: AtomicU64,
    device_ops: AtomicU64,
    main_steps: AtomicU64,
    side_steps: AtomicU64,
    fused_ticks: AtomicU64,
    main_deferred: AtomicU64,
    active: AtomicUsize,
    parked: AtomicUsize,
    parked_peak: AtomicUsize,
}

impl Gauges {
    fn new() -> Gauges {
        Gauges {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            ticks: AtomicU64::new(0),
            device_ops: AtomicU64::new(0),
            main_steps: AtomicU64::new(0),
            side_steps: AtomicU64::new(0),
            fused_ticks: AtomicU64::new(0),
            main_deferred: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            parked: AtomicUsize::new(0),
            parked_peak: AtomicUsize::new(0),
        }
    }

    /// Tasks accepted but whose outcome is not yet in the results channel.
    fn in_flight(&self) -> usize {
        let s = self.submitted.load(Ordering::SeqCst);
        let c = self.completed.load(Ordering::SeqCst);
        s.saturating_sub(c) as usize
    }
}

struct MainReq {
    token: i32,
    pos: i32,
    paged: PagedKv,
    capacity: usize,
    reply: mpsc::Sender<Result<RawDecode>>,
}

enum Cmd {
    Main(MainReq),
    Task(SideTask),
}

/// The unified step scheduler.  Share via `Arc`; one per [`super::WarpCortex`].
pub struct StepScheduler {
    tx: Mutex<Option<mpsc::Sender<Cmd>>>,
    results_rx: Mutex<mpsc::Receiver<SideOutcome>>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
    gauges: Arc<Gauges>,
    max_pending: usize,
}

impl StepScheduler {
    /// Spawn the tick loop over the three seams.  Production callers build
    /// the seams from an engine + prism/synapse (see `WarpCortex::new`);
    /// tests and benches inject host-only stubs.
    pub fn new(
        mut cfg: StepConfig,
        exec: FusedExec,
        spawner: AgentSpawner,
        admit: AdmitGate,
    ) -> Arc<StepScheduler> {
        // A zero width would collect no side items while agents sit active
        // forever (a hot spin); one lane is the meaningful minimum.
        cfg.batch_width = cfg.batch_width.max(1);
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (results_tx, results_rx) = mpsc::channel::<SideOutcome>();
        let gauges = Arc::new(Gauges::new());
        let max_pending = cfg.max_active + cfg.max_parked;
        let g = gauges.clone();
        let handle = std::thread::Builder::new()
            .name("warp-step".into())
            .spawn(move || step_loop(cfg, rx, results_tx, exec, spawner, admit, g))
            .expect("spawn step scheduler");
        Arc::new(StepScheduler {
            tx: Mutex::new(Some(tx)),
            results_rx: Mutex::new(results_rx),
            handle: Mutex::new(Some(handle)),
            gauges,
            max_pending,
        })
    }

    /// One main-agent decode step through the scheduler (blocks until the
    /// result lands; appends the new KV row to `kv` on success).  The
    /// request ships the O(k) block table only — sound because this caller
    /// blocks on the reply, so the referenced blocks stay exclusively owned
    /// by `kv` for the whole step.
    pub fn main_step(&self, token: i32, pos: i32, kv: &mut KvCache) -> Result<MainStepOut> {
        if kv.remaining() == 0 {
            bail!("main_step: kv cache full");
        }
        let (reply_tx, reply_rx) = mpsc::channel();
        let req = MainReq {
            token,
            pos,
            paged: kv.paged(),
            capacity: kv.capacity(),
            reply: reply_tx,
        };
        let tx = lock_unpoisoned(&self.tx)
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("step scheduler shut down"))?;
        tx.send(Cmd::Main(req))
            .map_err(|_| anyhow!("step scheduler thread gone"))?;
        drop(tx);
        let raw = reply_rx
            .recv()
            .map_err(|_| anyhow!("step scheduler shut down while a main step was in flight"))??;
        kv.append_row(&raw.k_new, &raw.v_new)?;
        Ok(MainStepOut {
            logits: raw.logits,
            hidden: raw.hidden,
        })
    }

    /// Submit a side task; `false` means the park queue is full (caller
    /// drops it — the paper's side agents are best-effort by design).
    pub fn submit(&self, task: SideTask) -> bool {
        // Serialize the backpressure check under the tx lock; `completed`
        // only grows concurrently, which merely frees capacity.
        let guard = lock_unpoisoned(&self.tx);
        let Some(tx) = guard.as_ref() else {
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        };
        if self.gauges.in_flight() >= self.max_pending {
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // Count BEFORE sending so `in_flight()` can never under-report a
        // task the loop is already processing.
        self.gauges.submitted.fetch_add(1, Ordering::SeqCst);
        if tx.send(Cmd::Task(task)).is_err() {
            self.gauges.completed.fetch_add(1, Ordering::SeqCst); // net zero
            self.gauges.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Non-blocking poll for finished side agents (the episode loop calls
    /// this between main steps).
    pub fn poll_results(&self) -> Vec<SideOutcome> {
        let rx = lock_unpoisoned(&self.results_rx);
        let mut out = Vec::new();
        while let Ok(r) = rx.try_recv() {
            out.push(r);
        }
        out
    }

    /// Blocking wait for the next side outcome with a timeout.
    pub fn wait_result(&self, timeout: Duration) -> Option<SideOutcome> {
        let rx = lock_unpoisoned(&self.results_rx);
        rx.recv_timeout(timeout).ok()
    }

    /// Side tasks accepted but not yet delivered as outcomes.  The loop
    /// sends every outcome *before* counting it completed, so
    /// `in_flight() == 0` guarantees the outcomes are already retrievable.
    pub fn in_flight(&self) -> usize {
        self.gauges.in_flight()
    }

    /// Wait until no side task is active or parked (or timeout).
    pub fn drain(&self, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.in_flight() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        true
    }

    pub fn stats(&self) -> StepStats {
        let g = &self.gauges;
        StepStats {
            submitted: g.submitted.load(Ordering::Relaxed),
            completed: g.completed.load(Ordering::Relaxed),
            rejected_capacity: g.rejected.load(Ordering::Relaxed),
            active: g.active.load(Ordering::Relaxed),
            parked: g.parked.load(Ordering::Relaxed),
            parked_peak: g.parked_peak.load(Ordering::Relaxed),
            admitted: g.admitted.load(Ordering::Relaxed),
            ticks: g.ticks.load(Ordering::Relaxed),
            device_ops: g.device_ops.load(Ordering::Relaxed),
            main_steps: g.main_steps.load(Ordering::Relaxed),
            side_steps: g.side_steps.load(Ordering::Relaxed),
            fused_ticks: g.fused_ticks.load(Ordering::Relaxed),
            main_deferred: g.main_deferred.load(Ordering::Relaxed),
        }
    }

    /// Stop the tick loop.  In-flight main steps error out; active and
    /// parked side tasks surface as `Failed` outcomes (delivered before the
    /// loop exits, so a final `poll_results` still observes them).
    /// Idempotent.
    pub fn shutdown(&self) {
        let tx = lock_unpoisoned(&self.tx).take();
        drop(tx);
        if let Some(h) = lock_unpoisoned(&self.handle).take() {
            let _ = h.join();
        }
    }
}

impl Drop for StepScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn deliver(results: &mpsc::Sender<SideOutcome>, gauges: &Gauges, outcome: SideOutcome) {
    let _ = results.send(outcome);
    // AFTER the send: in_flight() == 0 implies the outcome is retrievable.
    gauges.completed.fetch_add(1, Ordering::SeqCst);
}

fn failed_outcome(task: SideTask, error: String) -> SideOutcome {
    SideOutcome {
        elapsed: task.spawned_at.elapsed(),
        task,
        state: SideState::Failed,
        text: String::new(),
        tokens: vec![],
        hidden: vec![],
        steps: 0,
        synapse_version: 0,
        error: Some(error),
    }
}

#[allow(clippy::too_many_lines)]
fn step_loop(
    cfg: StepConfig,
    rx: mpsc::Receiver<Cmd>,
    results: mpsc::Sender<SideOutcome>,
    exec: FusedExec,
    spawner: AgentSpawner,
    admit: AdmitGate,
    gauges: Arc<Gauges>,
) {
    let mut active: Vec<SideAgent> = Vec::new();
    let mut parked: VecDeque<SideTask> = VecDeque::new();
    let mut mains: VecDeque<MainReq> = VecDeque::new();
    // Round-robin cursor so `max_active > batch_width` populations are
    // served fairly across ticks.
    let mut rr: usize = 0;
    let mut open = true;

    fn enqueue(cmd: Cmd, mains: &mut VecDeque<MainReq>, parked: &mut VecDeque<SideTask>) {
        match cmd {
            Cmd::Main(m) => mains.push_back(m),
            Cmd::Task(t) => parked.push_back(t),
        }
    }

    loop {
        // ── 1. take on new work ─────────────────────────────────────────
        if open {
            if active.is_empty() && parked.is_empty() && mains.is_empty() {
                gauges.active.store(0, Ordering::Relaxed);
                gauges.parked.store(0, Ordering::Relaxed);
                // Fully idle: block until there is something to do.
                match rx.recv() {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut parked),
                    Err(_) => open = false,
                }
            }
            loop {
                match rx.try_recv() {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut parked),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
        }
        if !open {
            // Shutdown: fail everything still pending (delivered like any
            // other outcome) and exit.  Episode loops drain before the
            // orchestrator drops, so this only fires on teardown.
            for m in mains.drain(..) {
                let _ = m.reply.send(Err(anyhow!("step scheduler shut down")));
            }
            for t in parked.drain(..) {
                deliver(&results, &gauges, failed_outcome(t, "step scheduler shut down".into()));
            }
            for mut a in active.drain(..) {
                a.fail("step scheduler shut down".into());
                deliver(&results, &gauges, a.into_outcome());
            }
            return;
        }

        // ── 2. continuous admission: refill freed slots every tick ──────
        while active.len() < cfg.max_active && !parked.is_empty() && admit() {
            let task = parked.pop_front().expect("parked is non-empty");
            gauges.admitted.fetch_add(1, Ordering::Relaxed);
            let agent = spawner(task);
            if agent.is_done() {
                // born-failed (registration/seeding error)
                deliver(&results, &gauges, agent.into_outcome());
            } else {
                active.push(agent);
            }
        }
        gauges.active.store(active.len(), Ordering::Relaxed);
        gauges.parked.store(parked.len(), Ordering::Relaxed);
        gauges.parked_peak.fetch_max(parked.len(), Ordering::Relaxed);

        // ── 3. collect this tick's work items ───────────────────────────
        let main_req = mains.pop_front();
        let main_item = main_req.as_ref().map(|m| FusedReq {
            token: m.token,
            pos: m.pos,
            paged: m.paged.clone(),
        });
        // Reserve lane 0 only for a main that can actually fuse; a main
        // whose context has outgrown a side lane runs as its own op ahead
        // of the batch, so the sides keep the full width.
        let main_can_fuse = cfg.fuse_main
            && main_req
                .as_ref()
                .map_or(false, |m| m.paged.len + 1 <= cfg.side_ctx);
        let side_budget = if main_can_fuse {
            cfg.batch_width.saturating_sub(1)
        } else {
            cfg.batch_width
        };
        let mut idx: Vec<usize> = Vec::new();
        let mut sides: Vec<FusedReq> = Vec::new();
        let n = active.len();
        for k in 0..n {
            if sides.len() >= side_budget {
                break;
            }
            let i = (rr + k) % n;
            if let Some((token, pos)) = active[i].next_request() {
                sides.push(FusedReq {
                    token,
                    pos,
                    paged: active[i].paged(),
                });
                idx.push(i);
            }
        }
        if n > 0 {
            rr = (rr + 1) % n;
        }

        if main_item.is_none() && sides.is_empty() {
            // Nothing runnable: sweep agents that just finished; if tasks
            // are parked behind the capacity gate, wait briefly for blocks
            // to free (or for new commands) instead of spinning hot.
            sweep_done(&mut active, &results, &gauges);
            if active.is_empty() && !parked.is_empty() {
                match rx.recv_timeout(Duration::from_millis(1)) {
                    Ok(cmd) => enqueue(cmd, &mut mains, &mut parked),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
                }
            }
            continue;
        }

        // ── 4. one fused tick ───────────────────────────────────────────
        gauges.ticks.fetch_add(1, Ordering::Relaxed);
        if !mains.is_empty() {
            // Only other *mains* ever wait a tick; never side work.
            gauges
                .main_deferred
                .fetch_add(mains.len() as u64, Ordering::Relaxed);
        }
        let main_capacity = main_req.as_ref().map(|m| m.capacity).unwrap_or(0);
        // Contain executor panics like the legacy batcher: this tick's
        // participants get Err/Failed results, the loop keeps serving.
        let tick = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec(main_item.as_ref(), main_capacity, &sides, cfg.fuse_main)
        }))
        .unwrap_or_else(|_| Err(anyhow!("fused executor panicked")));
        match tick {
            Ok(FusedOut {
                main,
                sides: side_out,
                side_error,
                device_ops,
            }) => {
                gauges.device_ops.fetch_add(device_ops, Ordering::Relaxed);
                if device_ops == 1 && main_item.is_some() && !idx.is_empty() {
                    gauges.fused_ticks.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(req) = main_req {
                    gauges.main_steps.fetch_add(1, Ordering::Relaxed);
                    let reply = match main {
                        Some(raw) => Ok(raw),
                        None => Err(anyhow!("fused executor returned no main result")),
                    };
                    let _ = req.reply.send(reply);
                }
                if let Some(msg) = side_error {
                    // The side half of an unfused tick failed after the
                    // main op succeeded: fail only these lanes.
                    for slot in &idx {
                        active[*slot].fail(format!("side batch failed: {msg}"));
                    }
                } else {
                    let fed = idx.len().min(side_out.len());
                    for (slot, raw) in idx[..fed].iter().zip(side_out) {
                        gauges.side_steps.fetch_add(1, Ordering::Relaxed);
                        active[*slot].feed(raw);
                    }
                    for slot in &idx[fed..] {
                        active[*slot]
                            .fail("fused executor dropped this lane's result".into());
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if let Some(req) = main_req {
                    let _ = req.reply.send(Err(anyhow!("{msg}")));
                }
                for slot in &idx {
                    active[*slot].fail(format!("fused decode failed: {msg}"));
                }
            }
        }

        // ── 5. sweep: deliver finished agents; slots refill next tick ───
        sweep_done(&mut active, &results, &gauges);
        gauges.active.store(active.len(), Ordering::Relaxed);
    }
}

fn sweep_done(active: &mut Vec<SideAgent>, results: &mpsc::Sender<SideOutcome>, gauges: &Gauges) {
    let mut i = 0;
    while i < active.len() {
        if active[i].is_done() {
            let agent = active.swap_remove(i);
            deliver(results, gauges, agent.into_outcome());
        } else {
            i += 1;
        }
    }
}

/// Deterministic host-only stand-ins for the fused executor, shared by the
/// equivalence proptest below and `benches/continuous_batch.rs` — ONE home
/// for the op-accounting rules the CI thresholds assert against, so the
/// bench can never drift from the semantics the tests pin.  Hidden: not
/// part of the serving API.
#[doc(hidden)]
pub mod testing {
    use super::*;
    use crate::runtime::ModelConfig;
    use crate::util::rng::XorShift;

    /// Deterministic per-item decode stub: depends ONLY on
    /// `(token, pos, view len)`, so a step's result is identical whether it
    /// ran fused or sequential — exactly the property the real engine's
    /// batch==single numerics tests establish on-device.
    pub fn stub_raw(cfg: &ModelConfig, token: i32, pos: i32, len: usize) -> RawDecode {
        let row = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
        let seed = 0x57E9_0000_0000_0000
            ^ ((token as u64) << 40)
            ^ ((pos as u64) << 20)
            ^ len as u64;
        let mut rng = XorShift::new(seed);
        RawDecode {
            logits: (0..cfg.vocab_size).map(|_| rng.range_f32(-4.0, 4.0)).collect(),
            hidden: (0..cfg.d_model).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            k_new: (0..row).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
            v_new: (0..row).map(|_| rng.range_f32(-1.0, 1.0)).collect(),
        }
    }

    /// Host-only fused executor mirroring [`crate::model::Engine::decode_fused`]'s
    /// op accounting (1 op fused / sides-only / main-only, 2 when an
    /// unfusable main runs ahead of the side batch).
    pub fn stub_exec(cfg: ModelConfig, side_ctx: usize, batch_width: usize) -> FusedExec {
        Arc::new(move |main, _main_cap, sides, fuse_main| {
            if main.is_none() && sides.is_empty() {
                anyhow::bail!("empty tick");
            }
            let main_out = main.map(|m| stub_raw(&cfg, m.token, m.pos, m.paged.len));
            let side_out: Vec<RawDecode> = sides
                .iter()
                .map(|s| stub_raw(&cfg, s.token, s.pos, s.paged.len))
                .collect();
            let fused = match main {
                None => true,
                Some(m) => {
                    fuse_main && m.paged.len + 1 <= side_ctx && sides.len() + 1 <= batch_width
                }
            };
            let device_ops = if main.is_some() && !sides.is_empty() && !fused {
                2
            } else {
                1
            };
            Ok(FusedOut {
                main: main_out,
                sides: side_out,
                side_error: None,
                device_ops,
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::testing::{stub_exec, stub_raw};
    use super::*;
    use crate::cortex::agent::AgentCache;
    use crate::cortex::router::AgentRole;
    use crate::model::{KvPool, KvPoolConfig};
    use crate::runtime::ModelConfig;
    use crate::text::{SamplerConfig, Tokenizer};
    use crate::util::proptest::check;
    use std::sync::atomic::AtomicBool;
    use std::time::Instant;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 16,
            vocab_size: 260,
            head_dim: 4,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn task(id: u64, payload: &str) -> SideTask {
        SideTask {
            id,
            role: AgentRole::Verify,
            payload: payload.into(),
            main_pos: 0,
            spawned_at: Instant::now(),
        }
    }

    fn sampler_cfg(seed: u64) -> SamplerConfig {
        SamplerConfig {
            temperature: 0.8,
            top_k: 20,
            top_p: 0.9,
            repetition_penalty: 1.1,
            repetition_window: 16,
            seed,
        }
    }

    /// Spawner over bare pool caches: prompt ids derived from the payload,
    /// exactly what the sequential reference reconstructs per task.
    fn bare_spawner(
        pool: Arc<KvPool>,
        side_ctx: usize,
        gen_budget: usize,
        seed: u64,
    ) -> AgentSpawner {
        Arc::new(move |t: SideTask| {
            let prompt_ids = Tokenizer::new().encode(&t.payload, false);
            SideAgent::from_parts(
                t,
                AgentCache::Bare(pool.new_cache(side_ctx)),
                0,
                7,
                prompt_ids,
                gen_budget,
                sampler_cfg(seed),
            )
        })
    }

    /// Run one agent to completion against the per-item stub, sequentially
    /// (one device op per step) — the bit-identical reference.
    fn run_sequential(cfg: &ModelConfig, agent: &mut SideAgent) -> u64 {
        let mut ops = 0u64;
        while let Some((token, pos)) = agent.next_request() {
            let len = agent.paged().len;
            agent.feed(stub_raw(cfg, token, pos, len));
            ops += 1;
        }
        ops
    }

    fn assert_outcomes_match(got: &SideOutcome, want: &SideOutcome) {
        assert_eq!(got.task.id, want.task.id);
        assert_eq!(got.state, want.state, "task {}", want.task.id);
        assert_eq!(got.text, want.text, "task {}", want.task.id);
        assert_eq!(got.tokens, want.tokens, "task {}", want.task.id);
        assert_eq!(got.hidden, want.hidden, "task {}", want.task.id);
        assert_eq!(got.steps, want.steps, "task {}", want.task.id);
        assert_eq!(got.error, want.error, "task {}", want.task.id);
    }

    #[test]
    fn completes_tasks_and_fuses_ticks() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let side_ctx = 64;
        let sched = StepScheduler::new(
            StepConfig { batch_width: 4, side_ctx: 64, max_active: 4, max_parked: 16, fuse_main: true },
            stub_exec(cfg.clone(), side_ctx, 4),
            bare_spawner(pool, side_ctx, 8, 3),
            Arc::new(|| true),
        );
        for i in 0..6u64 {
            assert!(sched.submit(task(i, "check the cache")));
        }
        assert!(sched.drain(Duration::from_secs(5)), "tasks never finished");
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 6);
        let st = sched.stats();
        assert_eq!(st.completed, 6);
        assert!(st.side_steps > 0);
        // continuous batching must beat one-op-per-token
        assert!(
            st.device_ops < st.side_steps,
            "no fusion happened: {} ops for {} steps",
            st.device_ops,
            st.side_steps
        );
        sched.shutdown();
    }

    #[test]
    fn park_queue_backpressure_rejects_and_resumes_fifo() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let gate = Arc::new(AtomicBool::new(false));
        let g = gate.clone();
        let sched = StepScheduler::new(
            StepConfig { batch_width: 2, side_ctx: 64, max_active: 1, max_parked: 2, fuse_main: true },
            stub_exec(cfg.clone(), 64, 2),
            bare_spawner(pool, 64, 4, 1),
            Arc::new(move || g.load(Ordering::SeqCst)),
        );
        // Gate closed: everything parks; the 4th submit exceeds
        // max_active + max_parked and is rejected.
        assert!(sched.submit(task(1, "a")));
        assert!(sched.submit(task(2, "b")));
        assert!(sched.submit(task(3, "c")));
        assert!(!sched.submit(task(4, "d")), "park queue must backpressure");
        assert_eq!(sched.stats().rejected_capacity, 1);
        // Nothing admitted while the capacity gate is closed.
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(sched.stats().admitted, 0);
        assert!(sched.stats().parked >= 2, "tasks should be parked");
        // Open the gate: all three run and finish, FIFO.
        gate.store(true, Ordering::SeqCst);
        assert!(sched.drain(Duration::from_secs(5)), "parked tasks never resumed");
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 3);
        assert_eq!(
            outcomes.iter().map(|o| o.task.id).collect::<Vec<_>>(),
            vec![1, 2, 3],
            "admission must resume FIFO (max_active=1 serializes completion)"
        );
        sched.shutdown();
    }

    #[test]
    fn shutdown_fails_pending_work_but_delivers_outcomes() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let sched = StepScheduler::new(
            StepConfig { batch_width: 2, side_ctx: 64, max_active: 1, max_parked: 8, fuse_main: true },
            stub_exec(cfg.clone(), 64, 2),
            bare_spawner(pool, 64, 4, 1),
            Arc::new(|| false), // never admit: tasks stay parked
        );
        assert!(sched.submit(task(1, "x")));
        assert!(sched.submit(task(2, "y")));
        sched.shutdown();
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), 2, "parked tasks must surface on shutdown");
        for o in &outcomes {
            assert_eq!(o.state, SideState::Failed);
            assert!(o.error.as_deref().unwrap().contains("shut down"));
        }
        // post-shutdown requests error out instead of hanging
        let mut kv = KvPool::new(&tiny_cfg(), KvPoolConfig::default()).new_cache(64);
        assert!(sched.main_step(65, 0, &mut kv).is_err());
        assert!(!sched.submit(task(3, "z")));
    }

    /// A `side_error` tick (the engine's unfused 2-op path: main op
    /// succeeded, side batch failed) must fail ONLY the side lanes that
    /// were in the tick — and the scheduler keeps serving afterwards.
    #[test]
    fn side_error_fails_only_that_ticks_lanes() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig { block_tokens: 8, ..Default::default() });
        let poisoned = Arc::new(AtomicBool::new(true));
        let exec: FusedExec = {
            let cfg = cfg.clone();
            let poisoned = poisoned.clone();
            Arc::new(move |main, _mc, sides, _fuse| {
                if poisoned.load(Ordering::SeqCst) && !sides.is_empty() {
                    return Ok(FusedOut {
                        main: main.map(|m| stub_raw(&cfg, m.token, m.pos, m.paged.len)),
                        sides: Vec::new(),
                        side_error: Some("injected side fault".into()),
                        device_ops: 2,
                    });
                }
                let side_out = sides
                    .iter()
                    .map(|s| stub_raw(&cfg, s.token, s.pos, s.paged.len))
                    .collect();
                Ok(FusedOut {
                    main: main.map(|m| stub_raw(&cfg, m.token, m.pos, m.paged.len)),
                    sides: side_out,
                    side_error: None,
                    device_ops: 1,
                })
            })
        };
        let sched = StepScheduler::new(
            StepConfig { batch_width: 4, side_ctx: 64, max_active: 4, max_parked: 8, fuse_main: true },
            exec,
            bare_spawner(pool.clone(), 64, 4, 9),
            Arc::new(|| true),
        );
        // Both agents land in a poisoned tick: Failed, with the side-batch
        // message — while a concurrent main step still succeeds.
        assert!(sched.submit(task(1, "alpha")));
        assert!(sched.submit(task(2, "beta")));
        let mut main_kv = pool.new_cache(128);
        sched.main_step(5, 0, &mut main_kv).expect("main must survive a side fault");
        assert!(sched.drain(Duration::from_secs(5)));
        let got = sched.poll_results();
        assert_eq!(got.len(), 2);
        for o in &got {
            assert_eq!(o.state, SideState::Failed);
            assert!(o.error.as_deref().unwrap().contains("side batch failed"), "{:?}", o.error);
        }
        // Heal the executor: the scheduler keeps serving new tasks.
        poisoned.store(false, Ordering::SeqCst);
        assert!(sched.submit(task(3, "gamma")));
        assert!(sched.drain(Duration::from_secs(5)));
        let ok = sched.poll_results();
        assert_eq!(ok.len(), 1);
        assert!(ok[0].error.is_none(), "{:?}", ok[0].error);
        sched.shutdown();
    }

    /// The acceptance-criteria proptest: fused scheduling is bit-identical
    /// to the sequential per-agent path across random admit/park/finish
    /// interleavings (random widths, budgets, capacity-gate flaps and
    /// interleaved main steps).
    #[test]
    fn fused_equals_sequential_across_interleavings() {
        check("step scheduler ≡ sequential decode", 40, |g| {
            let cfg = tiny_cfg();
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig { block_tokens: 8, ..Default::default() },
            );
            let side_ctx = 64;
            let batch_width = g.usize_in(1..6);
            let max_active = g.usize_in(1..6);
            let fuse_main = g.bool();
            let n_tasks = g.usize_in(1..9);
            let gen_budget = g.usize_in(1..10);
            let seed = g.usize_in(1..1000) as u64;
            let main_steps = g.usize_in(0..12);

            // A capacity gate that flaps (deterministically) to exercise
            // parking + FIFO resume; numerics must be unaffected.
            let flap = Arc::new(AtomicU64::new(0));
            let admit: AdmitGate = {
                let flap = flap.clone();
                Arc::new(move || flap.fetch_add(1, Ordering::Relaxed) % 3 != 1)
            };
            let sched = StepScheduler::new(
                StepConfig { batch_width, side_ctx, max_active, max_parked: n_tasks + 1, fuse_main },
                stub_exec(cfg.clone(), side_ctx, batch_width),
                bare_spawner(pool.clone(), side_ctx, gen_budget, seed),
                admit,
            );

            let payloads: Vec<String> =
                (0..n_tasks).map(|i| format!("task {i} {}", g.usize_in(0..50))).collect();
            // Interleave submissions with main steps against a live cache.
            let mut main_kv = pool.new_cache(128);
            let mut twin_kv = pool.new_cache(128);
            let mut main_outs = Vec::new();
            let mut submitted = 0usize;
            for step in 0..main_steps.max(n_tasks) {
                if submitted < n_tasks {
                    crate::prop_assert!(
                        sched.submit(task(submitted as u64 + 1, &payloads[submitted])),
                        "submit {submitted} rejected below the bound"
                    );
                    submitted += 1;
                }
                if step < main_steps {
                    let tok = (step % 200) as i32;
                    let pos = main_kv.len() as i32;
                    let out = sched
                        .main_step(tok, pos, &mut main_kv)
                        .map_err(|e| format!("main step failed: {e:#}"))?;
                    main_outs.push(out);
                }
            }
            crate::prop_assert!(
                sched.drain(Duration::from_secs(10)),
                "scheduler never drained (width {batch_width}, active {max_active})"
            );
            let mut got = sched.poll_results();
            got.sort_by_key(|o| o.task.id);
            crate::prop_assert!(got.len() == n_tasks, "lost outcomes: {} of {n_tasks}", got.len());
            let st = sched.stats();
            crate::prop_assert!(st.main_deferred == 0, "single-main runs must never defer mains");
            sched.shutdown();

            // Sequential reference: identical parts, one op per step.
            for (i, payload) in payloads.iter().enumerate() {
                let t = task(i as u64 + 1, payload);
                let prompt_ids = Tokenizer::new().encode(payload, false);
                let mut reference = SideAgent::from_parts(
                    t,
                    AgentCache::Bare(pool.new_cache(side_ctx)),
                    0,
                    7,
                    prompt_ids,
                    gen_budget,
                    sampler_cfg(seed),
                );
                run_sequential(&cfg, &mut reference);
                assert_outcomes_match(&got[i], &reference.into_outcome());
            }
            // Main chain: bit-identical to the direct per-step stub path.
            for (step, out) in main_outs.iter().enumerate() {
                let tok = (step % 200) as i32;
                let pos = twin_kv.len() as i32;
                let want = stub_raw(&cfg, tok, pos, twin_kv.len());
                twin_kv
                    .append_row(&want.k_new, &want.v_new)
                    .map_err(|e| format!("twin append: {e:#}"))?;
                crate::prop_assert!(out.logits == want.logits, "main logits diverged at step {step}");
                crate::prop_assert!(out.hidden == want.hidden, "main hidden diverged at step {step}");
            }
            Ok(())
        });
    }
}
