//! The Topological Synapse (paper §3.3): a shared landmark buffer.
//!
//! The Main Agent periodically extracts the top-k landmark rows of its KV
//! cache (hybrid density-coverage sampling — the Layer-1 Pallas kernel) and
//! *pushes* them here.  Side agents *read* the latest snapshot and seed
//! their own caches from it: k rows instead of L — the `O(N·L) → O(N·k)`
//! claim.  Readers share one `Arc` snapshot ("zero-copy" in the paper's
//! terms: no per-reader duplication of the landmark buffer).
//!
//! Seeding itself is deduplicated through the pool's content-addressed
//! prefix registry: [`Synapse::seed_into`] keys the landmark rows on
//! `(snapshot version, landmark indices)` — which fully determine the row
//! contents — so the first side agent of a snapshot writes the seed blocks
//! once and every later agent attaches them *by reference* (zero copy,
//! zero host→device traffic for the shared blocks, CoW on divergence).
//! The shared-seed term of the O(N·k) context bound is thereby O(1) in N.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{bail, Result};

use super::memory::{MemGuard, MemKind, MemoryTracker};
use crate::model::{Engine, KvCache, SynapseOut};
use crate::util::sync::{LockRank, RankedMutex};

/// One immutable published landmark set.
#[derive(Debug)]
pub struct SynapseSnapshot {
    pub landmarks: SynapseOut,
    /// Monotone version (bumps on every push).
    pub version: u64,
    pub created: Instant,
}

impl SynapseSnapshot {
    /// Context compression ratio achieved by this snapshot (paper: 98 %).
    pub fn compression(&self) -> f64 {
        let k = self.landmarks.indices.len();
        if self.landmarks.source_len == 0 {
            0.0
        } else {
            1.0 - k as f64 / self.landmarks.source_len as f64
        }
    }

    /// **Hierarchical Synapse** (paper §6.2 future work #2): derive a
    /// coarser level-2 landmark set — the `k2` highest-scoring landmarks of
    /// this snapshot, in causal order.  Side agents with tight budgets seed
    /// from the coarse level; the fine level stays available.
    pub fn coarsen(&self, k2: usize) -> SynapseOut {
        let lm = &self.landmarks;
        let k = lm.indices.len();
        if k == 0 {
            return subset(lm, &[]);
        }
        let k2 = k2.min(k).max(1);
        // rank landmarks by score, keep top k2, restore causal order
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| sort_score(lm.scores[b]).total_cmp(&sort_score(lm.scores[a])));
        let mut keep: Vec<usize> = order[..k2].to_vec();
        keep.sort_unstable();

        subset(lm, &keep)
    }
}

/// NaN-proof descending-sort key: a NaN hybrid score ranks as lowest
/// priority (−∞) instead of aborting the orchestrator — the previous
/// `partial_cmp(..).unwrap()` panicked on the first NaN an extraction
/// produced.
fn sort_score(s: f32) -> f32 {
    if s.is_nan() {
        f32::NEG_INFINITY
    } else {
        s
    }
}

/// Gather the landmark subset `keep` (positions into the landmark list,
/// ascending) out of a `[L, K, KV, hd]`-shaped landmark set.
pub fn subset(lm: &SynapseOut, keep: &[usize]) -> SynapseOut {
    let k = lm.indices.len();
    let l = lm.n_layers.max(1);
    // k = 0 would divide by zero in the row-width computation; an empty
    // landmark set subsets to an empty set regardless of `keep`.
    if k == 0 || keep.is_empty() {
        return SynapseOut {
            lm_k: Vec::new(),
            lm_v: Vec::new(),
            indices: Vec::new(),
            scores: Vec::new(),
            source_len: lm.source_len,
            n_layers: lm.n_layers,
        };
    }
    let w = lm.lm_k.len() / (l * k); // row width = KV * hd
    let mut lm_k = Vec::with_capacity(l * keep.len() * w);
    let mut lm_v = Vec::with_capacity(l * keep.len() * w);
    for layer in 0..l {
        let base = layer * k * w;
        for &i in keep {
            lm_k.extend_from_slice(&lm.lm_k[base + i * w..base + (i + 1) * w]);
            lm_v.extend_from_slice(&lm.lm_v[base + i * w..base + (i + 1) * w]);
        }
    }
    SynapseOut {
        lm_k,
        lm_v,
        indices: keep.iter().map(|&i| lm.indices[i]).collect(),
        scores: keep.iter().map(|&i| lm.scores[i]).collect(),
        source_len: lm.source_len,
        n_layers: lm.n_layers,
    }
}

/// **Adaptive Landmark Selection** (paper §6.2 future work #1): shrink a
/// landmark set to the smallest k whose cumulative (normalised) hybrid
/// score mass reaches `target_mass` — simple contexts keep fewer landmarks,
/// complex ones keep all.  Result stays in causal order; at least
/// `min_k` landmarks are always retained.
pub fn adaptive_subset(lm: &SynapseOut, target_mass: f32, min_k: usize) -> SynapseOut {
    let k = lm.indices.len();
    let total: f32 = lm.scores.iter().map(|s| s.max(0.0)).sum();
    if total <= 0.0 {
        return subset(lm, &(0..k).collect::<Vec<_>>());
    }
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| sort_score(lm.scores[b]).total_cmp(&sort_score(lm.scores[a])));
    let mut mass = 0.0f32;
    let mut keep = Vec::new();
    for &i in &order {
        keep.push(i);
        mass += lm.scores[i].max(0.0) / total;
        if mass >= target_mass && keep.len() >= min_k {
            break;
        }
    }
    keep.sort_unstable();
    subset(lm, &keep)
}

/// Cumulative synapse statistics.
#[derive(Debug, Clone, Default)]
pub struct SynapseStats {
    pub pushes: u64,
    pub reads: u64,
    pub last_source_len: usize,
    pub last_version: u64,
}

/// The shared landmark buffer.
pub struct Synapse {
    current: RwLock<Option<Arc<SynapseSnapshot>>>,
    version: AtomicU64,
    reads: AtomicU64,
    /// Ranked [`LockRank::PrismAgents`] (same tier as the prism registry:
    /// leaf bookkeeping, never held across pool/scheduler locks).
    mem: RankedMutex<Option<MemGuard>>,
    tracker: Arc<MemoryTracker>,
}

impl Synapse {
    pub fn new(tracker: Arc<MemoryTracker>) -> Arc<Synapse> {
        Arc::new(Synapse {
            current: RwLock::new(None),
            version: AtomicU64::new(0),
            reads: AtomicU64::new(0),
            mem: RankedMutex::new(LockRank::PrismAgents, None),
            tracker,
        })
    }

    /// Publish a new landmark set (replaces the previous snapshot; existing
    /// readers keep their `Arc` until they drop it).
    pub fn push(&self, landmarks: SynapseOut) -> u64 {
        // Actual buffer bytes: f32 landmark K/V and scores, i32 indices —
        // all 4 bytes/element.  (The old formula charged 8 bytes per index
        // and skipped the scores, so the Table-2 synapse row drifted from
        // the real footprint; the accounting test now pins this to
        // `size_of_val` of the buffers.)
        let bytes = (landmarks.lm_k.len()
            + landmarks.lm_v.len()
            + landmarks.scores.len()
            + landmarks.indices.len()) as u64
            * 4;
        let version = self.version.fetch_add(1, Ordering::SeqCst) + 1;
        let snap = Arc::new(SynapseSnapshot {
            landmarks,
            version,
            created: Instant::now(),
        });
        {
            let mut mem = self.mem.lock();
            match mem.as_mut() {
                Some(g) => g.resize(bytes),
                None => *mem = Some(self.tracker.alloc(MemKind::Synapse, bytes)),
            }
        }
        *self.current.write().unwrap() = Some(snap);
        version
    }

    /// Read the latest snapshot (None until the first push).
    pub fn read(&self) -> Option<Arc<SynapseSnapshot>> {
        self.reads.fetch_add(1, Ordering::Relaxed);
        self.current.read().unwrap().clone()
    }

    pub fn version(&self) -> u64 {
        self.version.load(Ordering::SeqCst)
    }

    pub fn stats(&self) -> SynapseStats {
        let cur = self.current.read().unwrap();
        SynapseStats {
            pushes: self.version.load(Ordering::SeqCst),
            reads: self.reads.load(Ordering::Relaxed),
            last_source_len: cur.as_ref().map(|s| s.landmarks.source_len).unwrap_or(0),
            last_version: cur.as_ref().map(|s| s.version).unwrap_or(0),
        }
    }

    /// Seed a fresh side-agent cache from the latest snapshot.
    ///
    /// The side agent continues decoding at position `snapshot.source_len`
    /// (after the original context), so the landmark rows keep their
    /// original RoPE positions — the witness-complex reconstruction the
    /// paper describes.  Returns `(cache, continuation_pos, version)`.
    pub fn seed_side_cache(&self, engine: &Engine) -> Result<(KvCache, i32, u64)> {
        self.seed_side_cache_with(engine, SeedMode::Full)
    }

    /// Seeding with the §6.2 extensions: hierarchical (coarse level-2
    /// landmarks) or adaptive-k (score-mass-driven landmark count).
    pub fn seed_side_cache_with(
        &self,
        engine: &Engine,
        mode: SeedMode,
    ) -> Result<(KvCache, i32, u64)> {
        let mut kv = engine.new_side_cache();
        let (pos, version) = self.seed_into(&mut kv, mode)?;
        Ok((kv, pos, version))
    }

    /// Seed an *existing* cache in place (the pool-friendly path: side
    /// agents reuse the cache their prism ticket already rents, so landmark
    /// rows land in the shared block pool without an intermediate buffer).
    /// Clears the cache first.  Returns `(continuation_pos, version)`.
    ///
    /// Full landmark blocks are shared through the pool's prefix registry,
    /// keyed on the snapshot version plus the landmark indices: for a given
    /// version those two fully determine the row contents (the subset modes
    /// only choose *which* indices survive), so N side agents seeded from
    /// the same snapshot hold the same physical blocks — the first seeding
    /// writes them, the rest attach by reference and pay only the partial
    /// tail block.
    pub fn seed_into(&self, kv: &mut KvCache, mode: SeedMode) -> Result<(i32, u64)> {
        let Some(snap) = self.read() else {
            bail!("synapse is empty (no landmarks pushed yet)");
        };
        let lm = match mode {
            SeedMode::Full => None,
            SeedMode::Coarse(k2) => Some(snap.coarsen(k2)),
            SeedMode::Adaptive { target_mass, min_k } => {
                Some(adaptive_subset(&snap.landmarks, target_mass, min_k))
            }
        };
        let lm = lm.as_ref().unwrap_or(&snap.landmarks);
        let k = lm.indices.len();
        // Domain salt: the synapse's own namespace, folded with the
        // snapshot version — identical indices from *different* snapshots
        // must never collide in the registry.
        let salt = crate::model::chain_hash(
            SYNAPSE_CHAIN_SALT,
            &[snap.version as i32, (snap.version >> 32) as i32],
        );
        kv.replace_rows_keyed(k, salt, &lm.indices, &lm.lm_k, &lm.lm_v)?;
        Ok((lm.source_len as i32, snap.version))
    }
}

/// Domain salt for synapse landmark-seed chains in the pool's prefix
/// registry (prompt chains use [`crate::model::PROMPT_CHAIN_SALT`]).
const SYNAPSE_CHAIN_SALT: u64 = 0x5741_5250_5359_4e41; // "WARPSYNA"

/// How a side agent's cache is seeded from the synapse.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SeedMode {
    /// All k landmarks (the paper's base design).
    Full,
    /// Hierarchical Synapse (§6.2 #2): the coarse level-2 set of size k2.
    Coarse(usize),
    /// Adaptive Landmark Selection (§6.2 #1): smallest set reaching the
    /// target hybrid-score mass.
    Adaptive { target_mass: f32, min_k: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_landmarks(k: usize, source_len: usize, rows: usize) -> SynapseOut {
        SynapseOut {
            lm_k: vec![1.0; rows * k],
            lm_v: vec![2.0; rows * k],
            indices: (0..k as i32).collect(),
            scores: vec![0.5; k],
            source_len,
            n_layers: 1,
        }
    }

    #[test]
    fn push_read_versions() {
        let t = MemoryTracker::new();
        let s = Synapse::new(t.clone());
        assert!(s.read().is_none());
        let v1 = s.push(fake_landmarks(4, 100, 8));
        assert_eq!(v1, 1);
        let snap = s.read().unwrap();
        assert_eq!(snap.version, 1);
        assert!(snap.compression() > 0.9);
        let v2 = s.push(fake_landmarks(4, 120, 8));
        assert_eq!(v2, 2);
        // old snapshot still valid for holders
        assert_eq!(snap.landmarks.source_len, 100);
        assert_eq!(s.read().unwrap().landmarks.source_len, 120);
        assert_eq!(s.stats().pushes, 2);
        assert!(s.stats().reads >= 2);
    }

    #[test]
    fn memory_accounted_once_not_per_reader() {
        let t = MemoryTracker::new();
        let s = Synapse::new(t.clone());
        let lm = fake_landmarks(4, 100, 8);
        // the charge must equal the buffers' actual sizes, not a formula
        // that drifts from them (the old one: indices at 8 B, scores free)
        let expect = (std::mem::size_of_val(&lm.lm_k[..])
            + std::mem::size_of_val(&lm.lm_v[..])
            + std::mem::size_of_val(&lm.scores[..])
            + std::mem::size_of_val(&lm.indices[..])) as i64;
        s.push(lm);
        let before = t.live_bytes(MemKind::Synapse);
        assert_eq!(before, expect, "accounted bytes != actual buffer bytes");
        assert!(before > 0);
        let _r1 = s.read();
        let _r2 = s.read();
        let _r3 = s.read();
        assert_eq!(t.live_bytes(MemKind::Synapse), before, "readers are zero-copy");
        // replacing adjusts, not accumulates
        s.push(fake_landmarks(8, 100, 8));
        let after = t.live_bytes(MemKind::Synapse);
        assert!(after > before);
        s.push(fake_landmarks(4, 100, 8));
        assert_eq!(t.live_bytes(MemKind::Synapse), before);
    }

    fn structured_landmarks() -> SynapseOut {
        // L=2 layers, K=4 landmarks, row width w=3: lm_k[l][i][..] = l*100 + i
        let mut lm_k = Vec::new();
        for l in 0..2 {
            for i in 0..4 {
                lm_k.extend_from_slice(&[(l * 100 + i) as f32; 3]);
            }
        }
        SynapseOut {
            lm_v: lm_k.iter().map(|x| -x).collect(),
            lm_k,
            indices: vec![3, 10, 20, 30],
            scores: vec![0.1, 0.9, 0.3, 0.6],
            source_len: 40,
            n_layers: 2,
        }
    }

    #[test]
    fn coarsen_keeps_top_scores_in_causal_order() {
        let t = MemoryTracker::new();
        let s = Synapse::new(t);
        s.push(structured_landmarks());
        let snap = s.read().unwrap();
        let coarse = snap.coarsen(2);
        // top-2 scores are 0.9 (i=1) and 0.6 (i=3), causal order => [10, 30]
        assert_eq!(coarse.indices, vec![10, 30]);
        assert_eq!(coarse.scores, vec![0.9, 0.6]);
        assert_eq!(coarse.n_layers, 2);
        // layer 0 rows: landmarks 1 and 3 => values 1.0 and 3.0
        assert_eq!(&coarse.lm_k[..6], &[1.0, 1.0, 1.0, 3.0, 3.0, 3.0]);
        // layer 1 rows: 101 and 103
        assert_eq!(&coarse.lm_k[6..12], &[101.0, 101.0, 101.0, 103.0, 103.0, 103.0]);
        assert_eq!(coarse.lm_v[0], -1.0);
        // degenerate requests clamp
        assert_eq!(snap.coarsen(0).indices.len(), 1);
        assert_eq!(snap.coarsen(99).indices.len(), 4);
    }

    #[test]
    fn adaptive_subset_scales_k_with_mass() {
        let lm = structured_landmarks();
        // total mass 1.9; target 0.4 → 0.9/1.9 ≈ 0.47 ≥ 0.4 after 1 landmark
        let small = adaptive_subset(&lm, 0.4, 1);
        assert_eq!(small.indices, vec![10]);
        // target 0.99 → needs all 4
        let big = adaptive_subset(&lm, 0.99, 1);
        assert_eq!(big.indices.len(), 4);
        // min_k respected
        let floored = adaptive_subset(&lm, 0.01, 3);
        assert_eq!(floored.indices.len(), 3);
        // causal order always
        assert!(floored.indices.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn nan_scores_rank_last_instead_of_panicking() {
        // A single NaN hybrid score used to abort the orchestrator through
        // partial_cmp().unwrap(); it must now simply lose every comparison.
        let mut lm = structured_landmarks();
        lm.scores[1] = f32::NAN; // poisons what was the top score
        let t = MemoryTracker::new();
        let s = Synapse::new(t);
        s.push(lm);
        let snap = s.read().unwrap();
        let coarse = snap.coarsen(2);
        // top-2 of {0.1, NaN, 0.3, 0.6} is {0.6, 0.3} → causal [20, 30]
        assert_eq!(coarse.indices, vec![20, 30]);
        // adaptive: total mass 1.0 (NaN counts as 0); 0.99 needs the three
        // real scores and never the NaN landmark
        let ad = adaptive_subset(&snap.landmarks, 0.99, 1);
        assert_eq!(ad.indices, vec![3, 20, 30]);
        // an all-NaN set degrades gracefully rather than panicking
        let mut all_nan = structured_landmarks();
        for sc in all_nan.scores.iter_mut() {
            *sc = f32::NAN;
        }
        assert_eq!(adaptive_subset(&all_nan, 0.5, 1).indices.len(), 4);
        let t2 = MemoryTracker::new();
        let s2 = Synapse::new(t2);
        s2.push(all_nan);
        assert_eq!(s2.read().unwrap().coarsen(2).indices.len(), 2);
    }

    #[test]
    fn empty_landmark_set_is_safe() {
        // k = 0 used to divide by zero in subset's row-width computation.
        let lm = SynapseOut {
            lm_k: vec![],
            lm_v: vec![],
            indices: vec![],
            scores: vec![],
            source_len: 7,
            n_layers: 2,
        };
        let sub = subset(&lm, &[]);
        assert!(sub.indices.is_empty() && sub.lm_k.is_empty());
        assert_eq!(sub.source_len, 7);
        assert!(adaptive_subset(&lm, 0.5, 1).indices.is_empty());
        let t = MemoryTracker::new();
        let s = Synapse::new(t);
        s.push(lm);
        assert!(s.read().unwrap().coarsen(3).indices.is_empty());
    }

    #[test]
    fn concurrent_push_read_consistency() {
        use std::thread;
        let t = MemoryTracker::new();
        let s = Synapse::new(t);
        let writer = {
            let s = s.clone();
            thread::spawn(move || {
                for i in 1..=200usize {
                    s.push(fake_landmarks(4, 100 + i, 8));
                }
            })
        };
        let readers: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                thread::spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..500 {
                        if let Some(snap) = s.read() {
                            // versions never go backwards for a reader
                            assert!(snap.version >= last);
                            last = snap.version;
                            // snapshot is internally consistent
                            assert_eq!(snap.landmarks.indices.len(), 4);
                        }
                    }
                })
            })
            .collect();
        writer.join().unwrap();
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(s.version(), 200);
    }
}
