//! Dynamic batcher for side-agent decode steps — the **legacy** decode
//! path, subsumed on the serving path by [`super::step::StepScheduler`]
//! (iteration-level continuous batching).  Kept for thread-per-agent
//! callers ([`super::agent::run_side_agent`] on the
//! [`super::StreamScheduler`] worker pool) and as the linger-based
//! batching reference.
//!
//! Side agents run on independent threads; batching their per-token decode
//! ops amortises device dispatch overhead (the serving classic).  A worker
//! calls [`Batcher::decode`], which ships a request to the batcher thread;
//! the thread drains whatever is already queued and lingers up to `linger`
//! to collect up to `B` requests, issues one `decode_batch` op on the
//! Stream lane, and fans the results back out.  Single stragglers fall
//! through to the cheaper single-decode program.  (`linger == 0` is the
//! "never wait" knob: co-arriving requests that are *already queued* still
//! fuse — the pre-PR-4 code checked the deadline before its first
//! `recv_timeout` and so never batched at all with a zero linger.)
//!
//! Requests are **paged**: since the device-resident refactor a request
//! carries the cache's block table ([`crate::model::PagedKv`], O(k) ints) instead of
//! full-capacity K/V vectors, shrinking the channel's in-flight memory from
//! `O(B·capacity)` floats to `O(B·k)` and eliminating the per-token
//! full-cache upload.  This is sound because the requesting worker *blocks*
//! on the reply while the batcher resolves the table against the shared
//! pool's device copies — the blocks are exclusively owned by the waiting
//! cache and cannot be mutated, released or re-rented mid-step.
//!
//! Failure containment: the executor runs under `catch_unwind` (a
//! panicking batch surfaces as an `Err` reply to each caller in it, and
//! the batcher thread keeps serving), and every lock on the request path
//! is poison-tolerant ([`crate::util::sync`]) — one panicking worker can
//! no longer poison the `tx`/`handle` mutexes and cascade its failure
//! into every later `decode`/`shutdown` caller.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::model::{Engine, FusedReq, KvCache, RawDecode};
use crate::util::sync::{LockRank, RankedMutex};

/// Result of one batched decode step.
#[derive(Debug)]
pub struct StepOut {
    pub logits: Vec<f32>,
    pub hidden: Vec<f32>,
}

/// Executes one collected batch, returning one [`RawDecode`] per item
/// (same order).  Items are [`FusedReq`]s — the engine's per-lane work
/// unit (token, position, O(k) block table; never the cache contents,
/// which are device-resident already).  Production wraps the engine's
/// single/batched decode programs; tests inject recording or faulty
/// executors to drive the thread protocol host-only.
pub type BatchExec = Arc<dyn Fn(&[FusedReq]) -> Vec<Result<RawDecode>> + Send + Sync>;

struct Request {
    item: FusedReq,
    reply: mpsc::Sender<Result<RawDecode>>,
}

/// Batching statistics.
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub singles: u64,
}

impl BatcherStats {
    /// Mean requests per device op (>1 means batching is paying off).
    pub fn mean_batch_size(&self) -> f64 {
        let ops = self.batches + self.singles;
        if ops == 0 {
            0.0
        } else {
            self.requests as f64 / ops as f64
        }
    }
}

/// The dynamic batcher.  Clone-free: share via `Arc`.
pub struct Batcher {
    tx: RankedMutex<Option<mpsc::Sender<Request>>>,
    requests: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    singles: AtomicU64,
    handle: RankedMutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the batcher thread over an engine.  `linger` bounds the wait
    /// for co-batchable requests (the latency/throughput knob; 0 = fuse
    /// only what is already queued).
    pub fn new(engine: Arc<Engine>, linger: Duration) -> Arc<Batcher> {
        let b_max = engine.caps().decode_batch;
        // One home for side-batch assembly: the engine's `run_side_batch`
        // (also the step scheduler's sides-only path) picks the straggler
        // vs batch program and unpacks the lanes.
        let exec: BatchExec = Arc::new(move |items| {
            match engine.run_side_batch(items) {
                Ok(outs) => outs.into_iter().map(Ok).collect(),
                Err(e) => {
                    let msg = format!("{e:#}");
                    items.iter().map(|_| Err(anyhow!("{msg}"))).collect()
                }
            }
        });
        Batcher::with_exec(exec, linger, b_max)
    }

    /// Batcher over an arbitrary executor — the seam the linger/shutdown/
    /// panic regression tests drive without a device.  Production callers
    /// use [`Batcher::new`].
    pub fn with_exec(exec: BatchExec, linger: Duration, b_max: usize) -> Arc<Batcher> {
        let (tx, rx) = mpsc::channel::<Request>();
        let batcher = Arc::new(Batcher {
            tx: RankedMutex::new(LockRank::SchedulerQueue, Some(tx)),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            singles: AtomicU64::new(0),
            handle: RankedMutex::new(LockRank::SchedulerQueue, None),
        });
        let b = batcher.clone();
        let handle = std::thread::Builder::new()
            .name("warp-batcher".into())
            .spawn(move || batcher_thread(exec, rx, linger, b_max.max(1), b))
            .expect("spawn batcher");
        *batcher.handle.lock() = Some(handle);
        batcher
    }

    /// One decode step through the batcher (blocks until the result lands).
    /// Appends the new KV row to `kv` on success.
    pub fn decode(&self, token: i32, pos: i32, kv: &mut KvCache) -> Result<StepOut> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        // O(k) request payload: the block table + length.  The K/V rows are
        // already device-resident (written through on append); we block on
        // the reply below, so the referenced blocks stay exclusively ours
        // for the whole step.
        let req = Request {
            item: FusedReq {
                token,
                pos,
                paged: kv.paged(),
            },
            reply: reply_tx,
        };
        // Clone the sender under the (poison-tolerant) mutex, send outside
        // it: shutdown can take-and-drop the channel without ever racing a
        // held guard, and a panicked peer cannot cascade into this caller.
        let tx = self.tx.lock()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("batcher shut down"))?;
        tx.send(req).map_err(|_| anyhow!("batcher thread gone"))?;
        drop(tx);
        let raw = reply_rx
            .recv()
            .map_err(|_| anyhow!("batcher shut down while a decode was in flight"))??;
        kv.append_row(&raw.k_new, &raw.v_new)?;
        Ok(StepOut {
            logits: raw.logits,
            hidden: raw.hidden,
        })
    }

    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            singles: self.singles.load(Ordering::Relaxed),
        }
    }

    /// Stop the batcher thread (pending requests error out).
    ///
    /// Teardown order matters for orchestrator shutdown: the sender is
    /// *taken out under the mutex and dropped* before joining, so (a) any
    /// `decode` caller that races the teardown observes the empty slot and
    /// gets an immediate "batcher shut down" error, and (b) the batcher
    /// thread sees the channel disconnect, drains already-queued requests
    /// (replying to each), and exits — no caller is left hanging on a dead
    /// channel.  Idempotent: later calls find both slots empty.
    pub fn shutdown(&self) {
        let tx = self.tx.lock().take();
        drop(tx);
        if let Some(h) = self.handle.lock().take() {
            let _ = h.join();
        }
    }
}

fn batcher_thread(
    exec: BatchExec,
    rx: mpsc::Receiver<Request>,
    linger: Duration,
    b_max: usize,
    stats: Arc<Batcher>,
) {
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + linger;
        while batch.len() < b_max {
            // Drain already-queued requests FIRST: co-arrivals fuse even
            // with `linger == 0` (the old loop checked the deadline before
            // its first recv and degenerated to singles).
            match rx.try_recv() {
                Ok(r) => {
                    batch.push(r);
                    continue;
                }
                Err(mpsc::TryRecvError::Disconnected) => break,
                Err(mpsc::TryRecvError::Empty) => {}
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        if batch.len() == 1 {
            stats.singles.fetch_add(1, Ordering::Relaxed);
        } else {
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats
                .batched_requests
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
        }

        // Split payloads from repliers (no per-item clone on the hot path).
        let (items, replies): (Vec<FusedReq>, Vec<_>) =
            batch.into_iter().map(|r| (r.item, r.reply)).unzip();
        // Contain executor panics: the batch's callers get an Err reply,
        // the thread keeps serving, and (because callers never observe a
        // poisoned lock) later requests are unaffected.
        let results = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| exec(&items)))
            .unwrap_or_else(|_| {
                items
                    .iter()
                    .map(|_| Err(anyhow!("batch executor panicked")))
                    .collect()
            });
        if results.len() == replies.len() {
            for (reply, out) in replies.into_iter().zip(results) {
                let _ = reply.send(out);
            }
        } else {
            for reply in replies {
                let _ = reply.send(Err(anyhow!(
                    "batch executor returned {} results for {} requests",
                    results.len(),
                    items.len()
                )));
            }
        }
    }
}

// End-to-end batcher behaviour with a real engine (batch == single
// numerics, fan-out under concurrency) is covered in
// rust/tests/integration_cortex.rs; the thread protocol itself is
// unit-tested below through the `with_exec` seam (no engine needed).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{KvPool, KvPoolConfig};
    use crate::runtime::ModelConfig;
    use std::sync::{Condvar, Mutex};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d_model: 8,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 2,
            d_ff: 16,
            vocab_size: 260,
            head_dim: 4,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn row_floats(cfg: &ModelConfig) -> usize {
        cfg.n_layers * cfg.n_kv_heads * cfg.head_dim
    }

    /// Executor that records batch sizes and can be parked on a gate.
    struct GatedExec {
        gate: Arc<(Mutex<bool>, Condvar)>,
        sizes: Arc<Mutex<Vec<usize>>>,
    }

    impl GatedExec {
        fn new() -> (BatchExec, Arc<(Mutex<bool>, Condvar)>, Arc<Mutex<Vec<usize>>>) {
            let gate = Arc::new((Mutex::new(true), Condvar::new()));
            let sizes = Arc::new(Mutex::new(Vec::new()));
            let e = GatedExec {
                gate: gate.clone(),
                sizes: sizes.clone(),
            };
            let cfg = tiny_cfg();
            let row = row_floats(&cfg);
            let exec: BatchExec = Arc::new(move |items| {
                {
                    let (lock, cv) = &*e.gate;
                    let mut open = lock.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                }
                e.sizes.lock().unwrap().push(items.len());
                items
                    .iter()
                    .map(|it| {
                        Ok(RawDecode {
                            logits: vec![it.token as f32; 4],
                            hidden: vec![it.pos as f32; 4],
                            k_new: vec![0.5f32; row],
                            v_new: vec![0.25f32; row],
                        })
                    })
                    .collect()
            });
            (exec, gate, sizes)
        }
    }

    fn set_gate(gate: &Arc<(Mutex<bool>, Condvar)>, open: bool) {
        let (lock, cv) = &**gate;
        *lock.lock().unwrap() = open;
        cv.notify_all();
    }

    fn caches(n: usize) -> Vec<KvCache> {
        let pool = KvPool::new(&tiny_cfg(), KvPoolConfig::default());
        (0..n).map(|_| pool.new_cache(64)).collect()
    }

    /// The `linger == 0` regression: requests already queued while the
    /// executor was busy must still fuse into one batch — the old deadline
    /// check broke before the first recv and degenerated to singles.
    #[test]
    fn linger_zero_still_fuses_co_arrivals() {
        let (exec, gate, sizes) = GatedExec::new();
        let b = Batcher::with_exec(exec, Duration::ZERO, 8);
        // Park the executor on the first request so the next three queue up.
        set_gate(&gate, false);
        let workers: Vec<_> = (0..4)
            .map(|i| {
                let b = b.clone();
                let h = std::thread::spawn(move || {
                    let mut kv = caches(1).pop().unwrap();
                    b.decode(i, 0, &mut kv).map(|o| o.logits[0])
                });
                // Give request 0 time to be claimed before the rest queue.
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(30));
                }
                h
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        set_gate(&gate, true);
        for (i, h) in workers.into_iter().enumerate() {
            let logit = h.join().unwrap().unwrap();
            assert_eq!(logit, i as f32, "result fanned back to the wrong caller");
        }
        let sizes = sizes.lock().unwrap().clone();
        assert!(
            sizes.iter().any(|&s| s > 1),
            "linger==0 never fused co-arriving requests: batch sizes {sizes:?}"
        );
        assert!(b.stats().batches >= 1);
        b.shutdown();
    }

    /// Shutdown with requests still queued must drain them (each caller
    /// gets its reply) rather than stranding blocked workers.
    #[test]
    fn shutdown_with_queued_requests_drains_them() {
        let (exec, gate, sizes) = GatedExec::new();
        let b = Batcher::with_exec(exec, Duration::ZERO, 2);
        set_gate(&gate, false);
        let workers: Vec<_> = (0..5)
            .map(|i| {
                let b = b.clone();
                let h = std::thread::spawn(move || {
                    let mut kv = caches(1).pop().unwrap();
                    b.decode(i, 0, &mut kv).map(|o| o.logits[0])
                });
                if i == 0 {
                    std::thread::sleep(Duration::from_millis(30));
                }
                h
            })
            .collect();
        std::thread::sleep(Duration::from_millis(30));
        // Tear down while request 0 is mid-batch and 1..5 are queued; the
        // thread must drain the queue (channel items survive the sender
        // drop) before exiting, so shutdown's join completes and every
        // caller gets a reply.
        let shutter = {
            let b = b.clone();
            std::thread::spawn(move || b.shutdown())
        };
        std::thread::sleep(Duration::from_millis(30));
        set_gate(&gate, true);
        shutter.join().unwrap();
        for (i, h) in workers.into_iter().enumerate() {
            let logit = h.join().unwrap().unwrap();
            assert_eq!(logit, i as f32, "queued request {i} lost at shutdown");
        }
        assert_eq!(sizes.lock().unwrap().iter().sum::<usize>(), 5);
        // Post-shutdown requests fail fast; repeated shutdown is a no-op.
        let mut kv = caches(1).pop().unwrap();
        assert!(b.decode(9, 0, &mut kv).is_err());
        b.shutdown();
    }

    /// A panicking executor must surface as an `Err` to its own callers
    /// and leave the batcher fully serviceable — no poisoned locks, no
    /// dead thread.
    #[test]
    fn panicking_executor_does_not_poison_the_batcher() {
        let cfg = tiny_cfg();
        let row = row_floats(&cfg);
        let exec: BatchExec = Arc::new(move |items| {
            if items[0].token == 13 {
                panic!("executor blew up");
            }
            items
                .iter()
                .map(|it| {
                    Ok(RawDecode {
                        logits: vec![it.token as f32; 4],
                        hidden: vec![0.0; 4],
                        k_new: vec![0.1; row],
                        v_new: vec![0.2; row],
                    })
                })
                .collect()
        });
        let b = Batcher::with_exec(exec, Duration::ZERO, 4);
        let mut kv = caches(1).pop().unwrap();
        let err = b.decode(13, 0, &mut kv).unwrap_err();
        assert!(format!("{err:#}").contains("panicked"), "{err:#}");
        assert_eq!(kv.len(), 0, "failed step must not append a row");
        // The thread survived and later decodes (and stats/shutdown locks)
        // work — the pre-fix behaviour panicked in `lock().unwrap()` here.
        let out = b.decode(7, 0, &mut kv).unwrap();
        assert_eq!(out.logits[0], 7.0);
        assert_eq!(kv.len(), 1);
        assert_eq!(b.stats().requests, 2);
        b.shutdown();
    }
}
