//! Dynamic batcher for side-agent decode steps.
//!
//! Side agents run on independent threads; batching their per-token decode
//! ops amortises device dispatch overhead (the serving classic).  A worker
//! calls [`Batcher::decode`], which ships a request to the batcher thread;
//! the thread lingers briefly (`linger`) to collect up to `B` requests,
//! issues one `decode_batch` op on the Stream lane, and fans the results
//! back out.  Single stragglers fall through to the cheaper single-decode
//! program.
//!
//! Requests are **paged**: since the device-resident refactor a request
//! carries the cache's block table ([`PagedKv`], O(k) ints) instead of
//! full-capacity K/V vectors, shrinking the channel's in-flight memory from
//! `O(B·capacity)` floats to `O(B·k)` and eliminating the per-token
//! full-cache upload.  This is sound because the requesting worker *blocks*
//! on the reply while the batcher resolves the table against the shared
//! pool's device copies — the blocks are exclusively owned by the waiting
//! cache and cannot be mutated, released or re-rented mid-step.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::model::{Engine, KvCache, PagedKv};
use crate::runtime::Lane;

/// Result of one batched decode step.
#[derive(Debug)]
pub struct StepOut {
    pub logits: Vec<f32>,
    pub hidden: Vec<f32>,
}

struct Request {
    token: i32,
    pos: i32,
    /// Block table + valid length of the requesting cache — never the
    /// cache contents (those are device-resident already).
    paged: PagedKv,
    reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>>,
}

/// Batching statistics.
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_requests: u64,
    pub singles: u64,
}

impl BatcherStats {
    /// Mean requests per device op (>1 means batching is paying off).
    pub fn mean_batch_size(&self) -> f64 {
        let ops = self.batches + self.singles;
        if ops == 0 {
            0.0
        } else {
            self.requests as f64 / ops as f64
        }
    }
}

/// The dynamic batcher.  Clone-free: share via `Arc`.
pub struct Batcher {
    tx: Mutex<Option<mpsc::Sender<Request>>>,
    requests: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    singles: AtomicU64,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Batcher {
    /// Spawn the batcher thread.  `linger` bounds the wait for co-batchable
    /// requests (the latency/throughput knob).
    pub fn new(engine: Arc<Engine>, linger: Duration) -> Arc<Batcher> {
        let (tx, rx) = mpsc::channel::<Request>();
        let batcher = Arc::new(Batcher {
            tx: Mutex::new(Some(tx)),
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            singles: AtomicU64::new(0),
            handle: Mutex::new(None),
        });
        let b = batcher.clone();
        let handle = std::thread::Builder::new()
            .name("warp-batcher".into())
            .spawn(move || batcher_thread(engine, rx, linger, b))
            .expect("spawn batcher");
        *batcher.handle.lock().unwrap() = Some(handle);
        batcher
    }

    /// One decode step through the batcher (blocks until the result lands).
    /// Appends the new KV row to `kv` on success.
    pub fn decode(&self, token: i32, pos: i32, kv: &mut KvCache) -> Result<StepOut> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let (reply_tx, reply_rx) = mpsc::channel();
        // O(k) request payload: the block table + length.  The K/V rows are
        // already device-resident (written through on append); we block on
        // the reply below, so the referenced blocks stay exclusively ours
        // for the whole step.
        let req = Request {
            token,
            pos,
            paged: kv.paged(),
            reply: reply_tx,
        };
        // Clone the sender under the mutex, send outside it: shutdown can
        // take-and-drop the channel without ever racing a held guard.
        let tx = self
            .tx
            .lock()
            .unwrap()
            .as_ref()
            .cloned()
            .ok_or_else(|| anyhow!("batcher shut down"))?;
        tx.send(req).map_err(|_| anyhow!("batcher thread gone"))?;
        drop(tx);
        let (logits, hidden, k_new, v_new) = reply_rx
            .recv()
            .map_err(|_| anyhow!("batcher shut down while a decode was in flight"))??;
        kv.append_row(&k_new, &v_new)?;
        Ok(StepOut { logits, hidden })
    }

    pub fn stats(&self) -> BatcherStats {
        BatcherStats {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            singles: self.singles.load(Ordering::Relaxed),
        }
    }

    /// Stop the batcher thread (pending requests error out).
    ///
    /// Teardown order matters for orchestrator shutdown: the sender is
    /// *taken out under the mutex and dropped* before joining, so (a) any
    /// `decode` caller that races the teardown observes the empty slot and
    /// gets an immediate "batcher shut down" error, and (b) the batcher
    /// thread sees the channel disconnect, drains already-queued requests
    /// (replying to each), and exits — no caller is left hanging on a dead
    /// channel.  Idempotent: later calls find both slots empty.
    pub fn shutdown(&self) {
        let tx = self.tx.lock().unwrap().take();
        drop(tx);
        if let Some(h) = self.handle.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

fn batcher_thread(
    engine: Arc<Engine>,
    rx: mpsc::Receiver<Request>,
    linger: Duration,
    stats: Arc<Batcher>,
) {
    let b_max = engine.caps().decode_batch;
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut batch = vec![first];
        let deadline = std::time::Instant::now() + linger;
        while batch.len() < b_max {
            let now = std::time::Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        if batch.len() == 1 {
            // Straggler: cheaper single-decode program.
            stats.singles.fetch_add(1, Ordering::Relaxed);
            let req = batch.pop().unwrap();
            let result =
                engine.decode_side_raw(req.token, req.pos, &req.paged, Lane::Stream);
            let _ = req.reply.send(result);
            continue;
        }

        stats.batches.fetch_add(1, Ordering::Relaxed);
        stats
            .batched_requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        let n = batch.len();
        let mut tokens = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        let mut views = Vec::with_capacity(n);
        for r in &batch {
            tokens.push(r.token);
            pos.push(r.pos);
            views.push(r.paged.clone());
        }
        match engine.decode_batch_raw(n, tokens, pos, &views, Lane::Stream) {
            Ok(results) => {
                for (req, out) in batch.into_iter().zip(results) {
                    let _ = req.reply.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    let _ = req.reply.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

// End-to-end batcher behaviour (batch == single numerics, fan-out under
// concurrency) is covered in rust/tests/integration_cortex.rs.
