//! The Cortex Router (paper §3.4): regex-style intent extraction over the
//! Main Agent's *streaming* output, with just-in-time spawn policy.
//!
//! The scanner is an incremental state machine fed one byte at a time (the
//! decode loop produces bytes one by one), equivalent to matching
//! `\[(TAG): ([^\]]{1,max})\]` over the stream — a unit test checks literal
//! equivalence against the `regex` crate on random streams.

use std::collections::VecDeque;
use std::time::Instant;

/// What kind of side agent a trigger spawns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgentRole {
    /// Generic task worker (`[TASK: ...]`).
    Task,
    /// Fact recall (`[RECALL: ...]`).
    Recall,
    /// Verification / fact-check (`[VERIFY: ...]`).
    Verify,
}

impl AgentRole {
    pub fn name(&self) -> &'static str {
        match self {
            AgentRole::Task => "task",
            AgentRole::Recall => "recall",
            AgentRole::Verify => "verify",
        }
    }
}

/// A detected trigger.
#[derive(Debug, Clone, PartialEq)]
pub struct Trigger {
    pub role: AgentRole,
    pub tag: String,
    pub payload: String,
    /// Byte offset in the stream where `[` appeared.
    pub offset: usize,
}

#[derive(Debug, Clone, Copy)]
enum ScanState {
    /// Outside any pattern.
    Text,
    /// After `[`, collecting the tag.
    Tag,
    /// After `: `, collecting the payload.
    Payload,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Recognised tags, mapped to roles.
    pub tags: Vec<(String, AgentRole)>,
    /// Payloads longer than this abort the match (runaway guard).
    pub max_payload: usize,
    /// Suppress a trigger if an identical payload fired within this many
    /// stream bytes (dedup window).
    pub dedup_window: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            tags: vec![
                ("TASK".into(), AgentRole::Task),
                ("RECALL".into(), AgentRole::Recall),
                ("VERIFY".into(), AgentRole::Verify),
            ],
            max_payload: 96,
            dedup_window: 512,
        }
    }
}

/// Streaming trigger scanner + dedup policy.
#[derive(Debug)]
pub struct Router {
    cfg: RouterConfig,
    state: ScanState,
    tag_buf: String,
    payload_buf: String,
    match_start: usize,
    offset: usize,
    recent: VecDeque<(String, usize)>,
    pub triggers_seen: u64,
    pub triggers_suppressed: u64,
    created: Instant,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        Router {
            cfg,
            state: ScanState::Text,
            tag_buf: String::new(),
            payload_buf: String::new(),
            match_start: 0,
            offset: 0,
            recent: VecDeque::new(),
            triggers_seen: 0,
            triggers_suppressed: 0,
            created: Instant::now(),
        }
    }

    pub fn with_defaults() -> Router {
        Router::new(RouterConfig::default())
    }

    /// Feed one stream byte; returns a trigger if one completed here.
    pub fn feed_byte(&mut self, b: u8) -> Option<Trigger> {
        let c = b as char;
        let out = match self.state {
            ScanState::Text => {
                if c == '[' {
                    self.state = ScanState::Tag;
                    self.tag_buf.clear();
                    self.match_start = self.offset;
                }
                None
            }
            ScanState::Tag => {
                if c == ':' {
                    if self.known_role(&self.tag_buf).is_some() {
                        self.state = ScanState::Payload;
                        self.payload_buf.clear();
                    } else {
                        self.state = ScanState::Text;
                    }
                } else if c.is_ascii_uppercase() && self.tag_buf.len() < 16 {
                    self.tag_buf.push(c);
                } else if c == '[' {
                    // restart on nested open bracket
                    self.tag_buf.clear();
                    self.match_start = self.offset;
                } else {
                    self.state = ScanState::Text;
                }
                None
            }
            ScanState::Payload => {
                if c == ']' {
                    self.state = ScanState::Text;
                    self.finish_match()
                } else if c == '[' || self.payload_buf.len() >= self.cfg.max_payload {
                    self.state = if c == '[' { ScanState::Tag } else { ScanState::Text };
                    if c == '[' {
                        self.tag_buf.clear();
                        self.match_start = self.offset;
                    }
                    None
                } else {
                    self.payload_buf.push(c);
                    None
                }
            }
        };
        self.offset += 1;
        out
    }

    /// Feed a chunk; returns all triggers completed within it.
    pub fn feed(&mut self, text: &str) -> Vec<Trigger> {
        text.bytes().filter_map(|b| self.feed_byte(b)).collect()
    }

    fn known_role(&self, tag: &str) -> Option<AgentRole> {
        self.cfg
            .tags
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, r)| *r)
    }

    fn finish_match(&mut self) -> Option<Trigger> {
        let role = self.known_role(&self.tag_buf)?;
        let payload = self.payload_buf.trim().to_string();
        if payload.is_empty() {
            return None;
        }
        self.triggers_seen += 1;
        // dedup
        let cutoff = self.offset.saturating_sub(self.cfg.dedup_window);
        while matches!(self.recent.front(), Some((_, o)) if *o < cutoff) {
            self.recent.pop_front();
        }
        if self.recent.iter().any(|(p, _)| *p == payload) {
            self.triggers_suppressed += 1;
            return None;
        }
        self.recent.push_back((payload.clone(), self.offset));
        Some(Trigger {
            role,
            tag: self.tag_buf.clone(),
            payload,
            offset: self.match_start,
        })
    }

    pub fn uptime(&self) -> std::time::Duration {
        self.created.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn detects_simple_trigger() {
        let mut r = Router::with_defaults();
        let t = r.feed("thinking... [TASK: verify the math] and on we go");
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].role, AgentRole::Task);
        assert_eq!(t[0].payload, "verify the math");
        assert_eq!(t[0].offset, 12);
    }

    #[test]
    fn detects_across_chunk_boundaries() {
        let mut r = Router::with_defaults();
        assert!(r.feed("abc [VER").is_empty());
        assert!(r.feed("IFY: the da").is_empty());
        let t = r.feed("te] rest");
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].role, AgentRole::Verify);
        assert_eq!(t[0].payload, "the date");
    }

    #[test]
    fn unknown_tags_and_malformed_ignored() {
        let mut r = Router::with_defaults();
        assert!(r.feed("[WHAT: nope] [task: lowercase] [TASK no colon]").is_empty());
        assert!(r.feed("[TASK: ] empty payload").is_empty());
        // unterminated then a real one
        let t = r.feed("[TASK: runs [TASK: real] x");
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].payload, "real");
    }

    #[test]
    fn payload_length_capped() {
        let mut r = Router::with_defaults();
        let long = format!("[TASK: {}]", "x".repeat(500));
        assert!(r.feed(&long).is_empty());
        assert_eq!(r.feed("[TASK: ok]").len(), 1);
    }

    #[test]
    fn dedup_suppresses_repeats_within_window() {
        let mut r = Router::with_defaults();
        assert_eq!(r.feed("[TASK: same thing]").len(), 1);
        assert_eq!(r.feed(" filler [TASK: same thing]").len(), 0);
        assert_eq!(r.triggers_suppressed, 1);
        // outside the window it fires again
        let filler = "y".repeat(600);
        assert_eq!(r.feed(&format!("{filler}[TASK: same thing]")).len(), 1);
    }

    #[test]
    fn multiple_roles_in_one_stream() {
        let mut r = Router::with_defaults();
        let t = r.feed("[TASK: a] mid [RECALL: b] end [VERIFY: c]");
        let roles: Vec<_> = t.iter().map(|x| x.role).collect();
        assert_eq!(roles, vec![AgentRole::Task, AgentRole::Recall, AgentRole::Verify]);
    }

    #[test]
    fn equivalent_to_reference_regex_on_random_streams() {
        // The streaming scanner must agree with the obvious regex on
        // arbitrary byte soup (dedup disabled for the comparison).
        let re = regex::Regex::new(r"\[(TASK|RECALL|VERIFY): ([^\[\]]{1,96})\]").unwrap();
        check("router == regex", 300, |g| {
            let alphabet = b"ab []:TASKRECLVIFY ";
            let s = g.string_from(0..120, alphabet);
            let mut r = Router::new(RouterConfig {
                dedup_window: 0,
                ..RouterConfig::default()
            });
            let got: Vec<String> = r
                .feed(&s)
                .into_iter()
                .map(|t| format!("{}:{}", t.tag, t.payload))
                .collect();
            let want: Vec<String> = re
                .captures_iter(&s)
                .filter(|c| !c[2].trim().is_empty())
                .map(|c| format!("{}:{}", &c[1], c[2].trim()))
                .collect();
            crate::prop_assert!(got == want, "stream {s:?}: got {got:?} want {want:?}");
            Ok(())
        });
    }
}
