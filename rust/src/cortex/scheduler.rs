//! The Stream scheduler: the worker-pool half of the River & Stream topology
//! (paper §3.1).
//!
//! Device-level priority lives in `runtime::device` (River ops preempt
//! Stream ops at op granularity).  This module manages the *population*
//! side: a bounded pool of side-agent worker threads (the paper's
//! "just-in-time spawning" — an agent exists only while its task runs),
//! task admission, and result collection that the Main Agent polls between
//! its decode steps.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use super::agent::{run_side_agent, SideContext, SideOutcome, SideTask};

/// Scheduler statistics.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_capacity: u64,
    pub active: usize,
    pub queued: usize,
}

struct SharedQueue {
    tasks: Mutex<VecDeque<SideTask>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Bounded side-agent executor.
pub struct StreamScheduler {
    queue: Arc<SharedQueue>,
    results_rx: Mutex<mpsc::Receiver<SideOutcome>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    max_queue: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
}

impl StreamScheduler {
    /// Spawn `workers` side-agent threads sharing `ctx`.  At most
    /// `max_queue` tasks may wait beyond the running ones (backpressure).
    pub fn new(ctx: Arc<SideContext>, workers: usize, max_queue: usize) -> StreamScheduler {
        let queue = Arc::new(SharedQueue {
            tasks: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let (results_tx, results_rx) = mpsc::channel();
        let active = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers.max(1))
            .map(|i| {
                let queue = queue.clone();
                let ctx = ctx.clone();
                let tx = results_tx.clone();
                let active = active.clone();
                std::thread::Builder::new()
                    .name(format!("warp-stream-{i}"))
                    .spawn(move || worker_loop(queue, ctx, tx, active))
                    .expect("spawn stream worker")
            })
            .collect();
        StreamScheduler {
            queue,
            results_rx: Mutex::new(results_rx),
            workers: handles,
            active,
            max_queue,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Submit a task; `false` means the queue is full (caller drops it —
    /// the paper's agents are best-effort by design).
    pub fn submit(&self, task: SideTask) -> bool {
        let mut q = self.queue.tasks.lock().unwrap();
        if q.len() >= self.max_queue {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        q.push_back(task);
        drop(q);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.queue.cv.notify_one();
        true
    }

    /// Non-blocking poll for finished side agents (the Main Agent calls
    /// this between decode steps).
    pub fn poll_results(&self) -> Vec<SideOutcome> {
        let rx = self.results_rx.lock().unwrap();
        let mut out = Vec::new();
        while let Ok(r) = rx.try_recv() {
            self.completed.fetch_add(1, Ordering::Relaxed);
            out.push(r);
        }
        out
    }

    /// Blocking wait for the next result with a timeout.
    pub fn wait_result(&self, timeout: std::time::Duration) -> Option<SideOutcome> {
        let rx = self.results_rx.lock().unwrap();
        match rx.recv_timeout(timeout) {
            Ok(r) => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Tasks currently running or queued.
    pub fn in_flight(&self) -> usize {
        self.active.load(Ordering::Relaxed) + self.queue.tasks.lock().unwrap().len()
    }

    pub fn stats(&self) -> SchedulerStats {
        SchedulerStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected_capacity: self.rejected.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            queued: self.queue.tasks.lock().unwrap().len(),
        }
    }

    /// Drain: wait until nothing is running or queued (or timeout).
    pub fn drain(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.in_flight() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        true
    }

    pub fn shutdown(mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for StreamScheduler {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    queue: Arc<SharedQueue>,
    ctx: Arc<SideContext>,
    results: mpsc::Sender<SideOutcome>,
    active: Arc<AtomicUsize>,
) {
    loop {
        let task = {
            let mut q = queue.tasks.lock().unwrap();
            loop {
                if queue.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = queue.cv.wait(q).unwrap();
            }
        };
        active.fetch_add(1, Ordering::SeqCst);
        let outcome = run_side_agent(&ctx, task);
        active.fetch_sub(1, Ordering::SeqCst);
        if results.send(outcome).is_err() {
            return;
        }
    }
}

// Scheduler behaviour with a real engine is covered by
// rust/tests/integration_cortex.rs; queue-capacity/backpressure unit tests
// would require a mock engine, which the SideContext design intentionally
// avoids (it is exercised end-to-end instead).
