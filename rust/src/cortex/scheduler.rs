//! The Stream scheduler: the worker-pool half of the River & Stream topology
//! (paper §3.1) — the **legacy** thread-per-agent executor.  The serving
//! path runs side agents as pollable state machines under
//! [`super::step::StepScheduler`] (iteration-level continuous batching);
//! this pool remains for blocking [`run_side_agent`] callers.
//!
//! Device-level priority lives in `runtime::device` (River ops preempt
//! Stream ops at op granularity).  This module manages the *population*
//! side: a bounded pool of side-agent worker threads (the paper's
//! "just-in-time spawning" — an agent exists only while its task runs),
//! task admission, and result collection that the Main Agent polls between
//! its decode steps.  All queue/result locks are poison-tolerant
//! ([`crate::util::sync`]): a panicking worker's claim is released by the
//! `Claim` drop guard and its failure surfaces as a `Failed` outcome — it
//! never cascades a poisoned mutex into later submitters.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar};

use super::agent::{run_side_agent, SideContext, SideOutcome, SideState, SideTask};
use crate::util::sync::{ranked_wait, LockRank, RankedMutex};

/// The function a worker runs per claimed task.  Production wraps
/// [`run_side_agent`] (see [`StreamScheduler::new`]); tests inject stub
/// runners so the scheduler's claiming/drain protocol can be hammered
/// without a device.
pub type TaskRunner = Arc<dyn Fn(SideTask) -> SideOutcome + Send + Sync>;

/// Scheduler statistics.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected_capacity: u64,
    pub active: usize,
    pub queued: usize,
}

struct SharedQueue {
    /// Ranked [`LockRank::SchedulerQueue`]; workers claim under this lock
    /// (the drain-race protocol) holding nothing else.
    tasks: RankedMutex<VecDeque<SideTask>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Bounded side-agent executor.
pub struct StreamScheduler {
    queue: Arc<SharedQueue>,
    results_rx: RankedMutex<mpsc::Receiver<SideOutcome>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    max_queue: usize,
    submitted: AtomicU64,
    completed: AtomicU64,
    rejected: AtomicU64,
}

impl StreamScheduler {
    /// Spawn `workers` side-agent threads sharing `ctx`.  At most
    /// `max_queue` tasks may wait beyond the running ones (backpressure).
    pub fn new(ctx: Arc<SideContext>, workers: usize, max_queue: usize) -> StreamScheduler {
        StreamScheduler::with_runner(
            Arc::new(move |task| run_side_agent(&ctx, task)),
            workers,
            max_queue,
        )
    }

    /// Scheduler over an arbitrary task runner — the seam the drain-race
    /// regression tests drive (no engine required); production callers use
    /// [`StreamScheduler::new`].
    pub fn with_runner(runner: TaskRunner, workers: usize, max_queue: usize) -> StreamScheduler {
        let queue = Arc::new(SharedQueue {
            tasks: RankedMutex::new(LockRank::SchedulerQueue, VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let (results_tx, results_rx) = mpsc::channel();
        let active = Arc::new(AtomicUsize::new(0));
        let handles = (0..workers.max(1))
            .map(|i| {
                let queue = queue.clone();
                let runner = runner.clone();
                let tx = results_tx.clone();
                let active = active.clone();
                std::thread::Builder::new()
                    .name(format!("warp-stream-{i}"))
                    .spawn(move || worker_loop(queue, runner, tx, active))
                    .expect("spawn stream worker")
            })
            .collect();
        StreamScheduler {
            queue,
            results_rx: RankedMutex::new(LockRank::SchedulerQueue, results_rx),
            workers: handles,
            active,
            max_queue,
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    /// Submit a task; `false` means the queue is full (caller drops it —
    /// the paper's agents are best-effort by design).
    pub fn submit(&self, task: SideTask) -> bool {
        let mut q = self.queue.tasks.lock();
        if q.len() >= self.max_queue {
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        q.push_back(task);
        drop(q);
        self.submitted.fetch_add(1, Ordering::Relaxed);
        self.queue.cv.notify_one();
        true
    }

    /// Non-blocking poll for finished side agents (the Main Agent calls
    /// this between decode steps).
    pub fn poll_results(&self) -> Vec<SideOutcome> {
        let rx = self.results_rx.lock();
        let mut out = Vec::new();
        while let Ok(r) = rx.try_recv() {
            self.completed.fetch_add(1, Ordering::Relaxed);
            out.push(r);
        }
        out
    }

    /// Blocking wait for the next result with a timeout.
    pub fn wait_result(&self, timeout: std::time::Duration) -> Option<SideOutcome> {
        let rx = self.results_rx.lock();
        match rx.recv_timeout(timeout) {
            Ok(r) => {
                self.completed.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Tasks currently running or queued.
    ///
    /// Consistent by construction: workers *claim* a task (increment
    /// `active`) while still holding the queue lock, and this reads both
    /// gauges under that same lock — a task can never be observed in
    /// neither place.  Workers un-claim only after the outcome has been
    /// sent, so `in_flight() == 0` additionally guarantees every produced
    /// result is already observable via `poll_results`/`wait_result`.
    pub fn in_flight(&self) -> usize {
        let q = self.queue.tasks.lock();
        self.active.load(Ordering::SeqCst) + q.len()
    }

    pub fn stats(&self) -> SchedulerStats {
        let (active, queued) = {
            let q = self.queue.tasks.lock();
            (self.active.load(Ordering::SeqCst), q.len())
        };
        SchedulerStats {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected_capacity: self.rejected.load(Ordering::Relaxed),
            active,
            queued,
        }
    }

    /// Drain: wait until nothing is running or queued (or timeout).
    pub fn drain(&self, timeout: std::time::Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        while self.in_flight() > 0 {
            if std::time::Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        true
    }

    pub fn shutdown(mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for StreamScheduler {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Un-claims (decrements `active`) on drop — including on unwind — so no
/// code path can leak the claim and wedge `in_flight()` above zero forever
/// (which would make every future `drain()` time out).
struct Claim<'a>(&'a AtomicUsize);

impl Drop for Claim<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

fn worker_loop(
    queue: Arc<SharedQueue>,
    runner: TaskRunner,
    results: mpsc::Sender<SideOutcome>,
    active: Arc<AtomicUsize>,
) {
    loop {
        let task = {
            let mut q = queue.tasks.lock();
            loop {
                if queue.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    // Claim while still holding the queue lock.  Popping
                    // first and incrementing after released a window in
                    // which `in_flight()` read 0 with a task mid-flight —
                    // `drain()` and shutdown could report success with work
                    // outstanding (the PR-2 drain race).
                    active.fetch_add(1, Ordering::SeqCst);
                    break t;
                }
                q = ranked_wait(&queue.cv, q);
            }
        };
        let claim = Claim(&active);
        // Contain panics: a poisoned agent must not kill the worker thread
        // (with a small pool that would strand every queued task and wedge
        // drain); it surfaces as a Failed outcome like any other error.
        let fallback = task.clone();
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| runner(task)))
            .unwrap_or_else(|_| SideOutcome {
                elapsed: fallback.spawned_at.elapsed(),
                task: fallback,
                state: SideState::Failed,
                text: String::new(),
                tokens: vec![],
                hidden: vec![],
                steps: 0,
                synapse_version: 0,
                error: Some("side agent panicked".into()),
            });
        // Deliver BEFORE un-claiming: once `in_flight()` reads 0, the
        // outcome is guaranteed to be sitting in the results channel.
        let delivered = results.send(outcome).is_ok();
        drop(claim);
        if !delivered {
            return;
        }
    }
}

// Scheduler behaviour with a real engine is covered by
// rust/tests/integration_cortex.rs; the claiming/drain protocol itself is
// unit-tested below through the `with_runner` seam (no engine needed).

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cortex::agent::SideState;
    use crate::cortex::router::AgentRole;
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    fn task(id: u64) -> SideTask {
        SideTask {
            id,
            session: 0,
            role: AgentRole::Verify,
            payload: "x".into(),
            main_pos: 0,
            spawned_at: Instant::now(),
        }
    }

    fn outcome(task: SideTask) -> SideOutcome {
        SideOutcome {
            task,
            state: SideState::Finished,
            text: String::new(),
            tokens: vec![],
            hidden: vec![],
            steps: 0,
            synapse_version: 0,
            elapsed: Duration::from_millis(0),
            error: None,
        }
    }

    /// The drain-race regression hammer: a task must never be observable
    /// in neither the queue nor the active gauge while its outcome is
    /// still undelivered.  With the pre-fix ordering (pop → unlock →
    /// claim, and un-claim → send) this trips within a few hundred rounds.
    #[test]
    fn in_flight_never_drops_a_mid_flight_task() {
        let s = StreamScheduler::with_runner(Arc::new(outcome), 1, 64);
        for round in 0..500u64 {
            assert!(s.submit(task(round)));
            loop {
                if s.in_flight() == 0 {
                    // nothing queued, nothing active → the result MUST
                    // already be in the channel
                    let got = s.poll_results();
                    assert!(
                        !got.is_empty(),
                        "round {round}: in_flight()==0 but the outcome \
                         was not delivered — drain race"
                    );
                    break;
                }
                if s.wait_result(Duration::from_millis(1)).is_some() {
                    break;
                }
            }
        }
        assert!(s.drain(Duration::from_secs(1)));
        s.shutdown();
    }

    /// `drain()` returning true must mean every submitted task's outcome
    /// is already retrievable (submit hammered from several threads).
    #[test]
    fn drain_means_all_outcomes_delivered() {
        let s = Arc::new(StreamScheduler::with_runner(
            Arc::new(|t| {
                std::thread::sleep(Duration::from_micros(200));
                outcome(t)
            }),
            4,
            1024,
        ));
        let mut submitted = 0u64;
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let s = s.clone();
                std::thread::spawn(move || {
                    let mut ok = 0u64;
                    for i in 0..64u64 {
                        if s.submit(task(t * 1000 + i)) {
                            ok += 1;
                        }
                    }
                    ok
                })
            })
            .collect();
        for h in handles {
            submitted += h.join().unwrap();
        }
        assert!(s.drain(Duration::from_secs(10)), "drain timed out");
        // nothing was polled before the drain, so every outcome must now
        // be sitting in the channel
        let got = s.poll_results().len() as u64;
        assert_eq!(
            got, submitted,
            "drain reported success with {} of {submitted} outcomes missing",
            submitted - got
        );
    }

    /// A panicking runner must neither leak its claim nor kill the worker:
    /// with a single worker, an uncontained panic would strand every queued
    /// task and wedge `drain()` forever.  The panic surfaces as a Failed
    /// outcome and the worker keeps serving.
    #[test]
    fn panicking_runner_does_not_wedge_the_scheduler() {
        let s = StreamScheduler::with_runner(
            Arc::new(|t: SideTask| {
                if t.id == 1 {
                    panic!("side agent blew up");
                }
                outcome(t)
            }),
            1, // sole worker: it MUST survive the panic
            8,
        );
        assert!(s.submit(task(1)));
        assert!(s.submit(task(2)));
        assert!(
            s.drain(Duration::from_secs(5)),
            "panicked worker wedged the scheduler"
        );
        let got = s.poll_results();
        assert_eq!(got.len(), 2, "both outcomes must be delivered");
        let failed: Vec<_> = got.iter().filter(|o| o.error.is_some()).collect();
        assert_eq!(failed.len(), 1);
        assert_eq!(failed[0].task.id, 1);
        assert!(failed[0].error.as_deref().unwrap().contains("panicked"));
        s.shutdown();
    }

    #[test]
    fn queue_capacity_backpressure_rejects() {
        // One worker parked on a gate; max_queue = 2 beyond it.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = gate.clone();
        let s = StreamScheduler::with_runner(
            Arc::new(move |t| {
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                outcome(t)
            }),
            1,
            2,
        );
        assert!(s.submit(task(1)));
        // wait until the worker has claimed task 1 (queue empty, active 1)
        let deadline = Instant::now() + Duration::from_secs(2);
        while s.stats().queued != 0 || s.stats().active != 1 {
            assert!(Instant::now() < deadline, "worker never claimed");
            std::thread::yield_now();
        }
        assert!(s.submit(task(2)));
        assert!(s.submit(task(3)));
        assert!(!s.submit(task(4)), "queue past max_queue must reject");
        assert_eq!(s.stats().rejected_capacity, 1);
        assert_eq!(s.in_flight(), 3);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        assert!(s.drain(Duration::from_secs(5)));
        assert_eq!(s.poll_results().len(), 3);
        s.shutdown();
    }
}
