//! Warp-Cortex launcher.
//!
//! ```text
//! warp-cortex serve  [--model small] [--addr 127.0.0.1:8787] [--workers 2]
//! warp-cortex run    [--model small] [--prompt "..."] [--max-tokens 64]
//! warp-cortex council [--model small] [--prompt "..."] [--agents 4]
//! warp-cortex tables  [--model tiny]          # print Table 1 quick view
//! warp-cortex info                            # manifest + artifact summary
//! ```
//!
//! Requires `make artifacts` to have been run (Python is build-time only;
//! this binary never invokes it).

use std::sync::Arc;

use anyhow::Result;

use warp_cortex::cortex::{CortexConfig, WarpCortex};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Manifest};
use warp_cortex::serve::{serve, ServerConfig};
use warp_cortex::util::args::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("run") => cmd_run(&args),
        Some("council") => cmd_council(&args),
        Some("tables") => cmd_tables(&args),
        Some("info") => cmd_info(),
        _ => {
            eprintln!(
                "usage: warp-cortex <serve|run|council|tables|info> [options]\n\
                 see rust/src/main.rs for the option list"
            );
            Ok(())
        }
    }
}

fn build_cortex(args: &Args) -> Result<Arc<WarpCortex>> {
    let model = args.get_or("model", "small").to_string();
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let cfg = CortexConfig {
        model: model.clone(),
        max_side_agents: args.get_usize("agents", 4),
        side_gen_budget: args.get_usize("side-budget", 24),
        inject_enabled: !args.flag("no-inject"),
        gate_theta: args.get("theta").and_then(|t| t.parse().ok()),
        ..CortexConfig::default()
    };
    Ok(Arc::new(WarpCortex::new(engine, cfg)?))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cortex = build_cortex(args)?;
    let cfg = ServerConfig {
        addr: args.get_or("addr", "127.0.0.1:8787").to_string(),
        workers: args.get_usize("workers", 2),
        max_tokens_cap: args.get_usize("max-tokens-cap", 128),
    };
    let handle = serve(cortex, cfg)?;
    println!("warp-cortex serving on http://{}", handle.addr);
    println!("  POST /generate  {{\"prompt\": \"...\", \"max_tokens\": 48}}");
    println!("  GET  /stats");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cortex = build_cortex(args)?;
    let prompt = args
        .get_or("prompt", "user: tell me about the kv cache.\nriver: ")
        .to_string();
    let max_tokens = args.get_usize("max-tokens", 64);
    let report = cortex.run_episode(&prompt, max_tokens)?;
    println!("── prompt ──\n{prompt}");
    println!("── generated ({} tokens) ──\n{}", report.tokens_generated, report.text);
    println!(
        "── {:.1} tok/s, p50 step {:.2} ms, {} events ──",
        report.main_tokens_per_sec,
        report.step_latency_p50_ns / 1e6,
        report.events.len()
    );
    Ok(())
}

fn cmd_council(args: &Args) -> Result<()> {
    let cortex = build_cortex(args)?;
    let prompt = args
        .get_or(
            "prompt",
            "user: tell me about the synapse. [TASK: verify the units] \
             [RECALL: the definition]\nriver: ",
        )
        .to_string();
    let report = cortex.run_episode(&prompt, args.get_usize("max-tokens", 96))?;
    println!("text: {}", report.text);
    println!("events:");
    for e in &report.events {
        println!("  {e:?}");
    }
    println!("gate: {:?}", report.gate);
    println!("inject: {:?}", report.inject);
    println!("memory: total {} bytes", report.memory.total());
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    // Delegated to the bench binaries for the full output; print the quick
    // analytic version here.
    use warp_cortex::cortex::memory::{fmt_bytes, MemoryModel};
    let manifest = Manifest::load(Manifest::default_dir())?;
    let qwen = manifest
        .analytic
        .get("qwen2_5_0_5b")
        .expect("analytic config");
    let m = MemoryModel::qwen05b_on_4090(qwen);
    println!("Table 1 (analytic, {}):", qwen.name);
    println!("  weights           {}", fmt_bytes(m.weight_bytes as f64));
    println!("  full context      {}", fmt_bytes(m.full_ctx_bytes() as f64));
    println!("  synapse (k=64)    {}", fmt_bytes(m.synapse_bytes() as f64));
    println!("  max agents std    {}", m.max_agents_standard());
    println!("  max agents warp   {}", m.max_agents_warp());
    let _ = args;
    Ok(())
}

fn cmd_info() -> Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    for (name, bundle) in &manifest.configs {
        println!(
            "config {name}: d={} L={} heads={}/{} params={}",
            bundle.model.d_model,
            bundle.model.n_layers,
            bundle.model.n_heads,
            bundle.model.n_kv_heads,
            bundle.model.param_count
        );
        for a in &bundle.artifacts {
            println!("  {} ({} flops)", a.name, a.flops);
        }
    }
    Ok(())
}
