"""Model + capacity configurations for Warp-Cortex.

Two runnable configs (``tiny`` for tests, ``small`` for examples/serving) plus
an analytic-only config (``qwen2_5_0_5b``) used by the Table-1/Table-2 memory
projections on the rust side.  The runnable configs are Qwen2-style
decoder-only transformers (RMSNorm, RoPE, GQA, SwiGLU) over a byte-level
vocabulary.

Vocabulary layout (byte-level, 260 symbols):
    0..255   raw bytes
    256      PAD
    257      BOS
    258      EOS
    259      REF   (marks Referential-Injection reference segments)
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

VOCAB_SIZE = 260
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
REF_ID = 259


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters of one Warp-Cortex model variant."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int = VOCAB_SIZE
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def gqa_groups(self) -> int:
        assert self.n_heads % self.n_kv_heads == 0
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Exact parameter count (embeddings tied with the LM head)."""
        d, f = self.d_model, self.d_ff
        per_layer = (
            2 * d  # ln1, ln2
            + d * self.n_heads * self.head_dim  # wq
            + 2 * d * self.n_kv_heads * self.head_dim  # wk, wv
            + self.n_heads * self.head_dim * d  # wo
            + 3 * d * f  # wg, wu, wd
        )
        return self.vocab_size * d + self.n_layers * per_layer + d  # + ln_f

    def to_json(self) -> dict:
        out = dataclasses.asdict(self)
        out["head_dim"] = self.head_dim
        out["param_count"] = self.param_count()
        return out


@dataclasses.dataclass(frozen=True)
class Capacities:
    """Buffer capacities that fix the shapes of the AOT-compiled programs."""

    prefill_len: int = 128  # S: padded prompt length for prefill
    main_ctx: int = 512  # C: main-agent KV capacity (incl. injection headroom)
    side_ctx: int = 96  # Cs: side-agent KV capacity (synapse_k + generation)
    synapse_k: int = 64  # K: landmark count ("k" in the paper, §3.3)
    inject_len: int = 16  # T: max thought length for referential injection
    decode_batch: int = 4  # B: side-agent dynamic-batch width

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


# ── Runnable configs ────────────────────────────────────────────────────────

TINY = ModelConfig(
    name="tiny", d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, d_ff=192
)
SMALL = ModelConfig(
    name="small", d_model=128, n_layers=4, n_heads=8, n_kv_heads=2, d_ff=384
)

# ── Analytic-only config (paper's testbed model; NEVER compiled here) ──────
# Qwen2.5-0.5B-Instruct: 24 layers, d=896, 14 query heads / 2 KV heads,
# head_dim 64, d_ff 4864, vocab 151936.  Used by rust cortex::memory for the
# Table-1 / Table-2 projections.
QWEN2_5_0_5B = ModelConfig(
    name="qwen2_5_0_5b",
    d_model=896,
    n_layers=24,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    rope_theta=1000000.0,
)

CONFIGS = {c.name: c for c in (TINY, SMALL)}
ANALYTIC_CONFIGS = {QWEN2_5_0_5B.name: QWEN2_5_0_5B}

# Default synapse-sampler hyper-parameters (paper §3.3: hybrid score
# s = alpha * attn_mass_hat + (1-alpha) * (1 - density_hat)).
DEFAULT_ALPHA = 0.5
# Gaussian-KDE bandwidth for the density term: sigma^2 = head-space scale.
def default_inv2sig2(cfg: ModelConfig) -> float:
    # keys live in R^{n_kv_heads * head_dim}; sigma^2 = dim gives a bandwidth
    # at the natural scale of RMS-normalised features.
    dim = cfg.n_kv_heads * cfg.head_dim
    return 1.0 / (2.0 * float(dim))


TRAIN_STEPS = {"tiny": 400, "small": 700}


def config_fingerprint(cfg: ModelConfig, caps: Capacities, steps: int, seed: int) -> str:
    """Stable hash over everything that affects trained weights + artifacts."""
    payload = json.dumps(
        {"cfg": cfg.to_json(), "caps": caps.to_json(), "steps": steps, "seed": seed},
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()[:16]
