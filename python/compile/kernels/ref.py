"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: ``pytest python/tests`` asserts that
every Pallas kernel matches its oracle to float32 tolerance across a
hypothesis-swept shape space.  They are also the "standard architecture"
compute path used by the training loop (no Pallas in the training hot loop).
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, valid_len):
    """Single-query GQA attention over a length-masked KV cache.

    Args:
      q:        [H, hd]     query heads for the current position.
      k_cache:  [C, KV, hd] cached (post-RoPE) keys; rows >= valid_len are junk.
      v_cache:  [C, KV, hd] cached values.
      valid_len: scalar i32; number of valid cache rows.

    Returns:
      out: [H, hd] attention output (pre-Wo).
    """
    H, hd = q.shape
    C, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(KV, G, hd)
    # scores: [KV, G, C]
    s = jnp.einsum("kgd,ckd->kgc", qg, k_cache) * scale
    pos = jnp.arange(C)[None, None, :]
    s = jnp.where(pos < valid_len, s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(pos < valid_len, p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("kgc,ckd->kgd", p, v_cache)
    return out.reshape(H, hd)


def hybrid_fields_ref(q, k_cache, valid_len, inv2sig2):
    """Reference for the hybrid density-coverage landmark fields (§3.3).

    Computes, per cached position i:
      attn[i] = sum_h softmax_i(q_h . K_i / sqrt(d_k))   (attention mass;
                the paper's "inverse kernel density estimator" numerator)
      rho[i]  = mean_{j < valid} exp(-||K_i - K_j||^2 * inv2sig2)
                (Gaussian kernel density over the key point-cloud, keys
                flattened across KV heads)

    Rows >= valid_len get attn = 0 and rho = 1 (max density => never chosen).

    Args:
      q:        [H, hd]
      k_cache:  [C, KV, hd]
      valid_len: scalar i32
      inv2sig2: scalar f32, 1 / (2 sigma^2)

    Returns:
      (attn[C], rho[C]) float32.
    """
    H, hd = q.shape
    C, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qg = q.reshape(KV, G, hd)
    s = jnp.einsum("kgd,ckd->kgc", qg, k_cache) * scale  # [KV, G, C]
    pos = jnp.arange(C)
    mask = pos < valid_len
    s = jnp.where(mask[None, None, :], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask[None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    attn = p.sum(axis=(0, 1))  # [C]; sums to H over valid positions

    flat = k_cache.reshape(C, KV * hd)
    sq = jnp.sum(flat * flat, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * flat @ flat.T
    d2 = jnp.maximum(d2, 0.0)
    ker = jnp.exp(-d2 * inv2sig2) * mask[None, :]
    denom = jnp.maximum(jnp.sum(mask), 1)
    rho = jnp.sum(ker, axis=-1) / denom
    rho = jnp.where(mask, rho, 1.0)
    attn = jnp.where(mask, attn, 0.0)
    return attn.astype(jnp.float32), rho.astype(jnp.float32)


def hybrid_scores_ref(q, k_cache, valid_len, alpha, inv2sig2):
    """Full hybrid landmark score (normalised mix of the two fields).

    s_i = alpha * attn_hat_i + (1 - alpha) * (1 - rho_hat_i), masked to
    valid positions (invalid positions get NEG_INF so top-k never picks
    them).  attn_hat / rho_hat are max-normalised over valid positions.
    """
    attn, rho = hybrid_fields_ref(q, k_cache, valid_len, inv2sig2)
    C = attn.shape[0]
    mask = jnp.arange(C) < valid_len
    attn_hat = attn / jnp.maximum(jnp.max(jnp.where(mask, attn, 0.0)), 1e-30)
    rho_hat = rho / jnp.maximum(jnp.max(jnp.where(mask, rho, 0.0)), 1e-30)
    score = alpha * attn_hat + (1.0 - alpha) * (1.0 - rho_hat)
    return jnp.where(mask, score, NEG_INF)
