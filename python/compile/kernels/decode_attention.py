"""L1 Pallas kernel: single-query flash-style decode attention (GQA).

The serving hot-spot: one query position attending over the agent's KV cache.
Written TPU-style (DESIGN.md §8):

  * the cache is streamed HBM -> VMEM in ``BC``-row tiles via ``BlockSpec``
    (this replaces the CUDA paper's threadblock tiling),
  * online-softmax running statistics (m, l, acc) live in VMEM scratch and
    persist across the sequential grid steps,
  * the score/value contractions are MXU-shaped matmuls per KV group.

Lowered with ``interpret=True`` — the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is estimated from the VMEM footprint in
DESIGN.md §7 / EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _block_c(C: int) -> int:
    """Largest cache-tile size <= 128 that divides the capacity C."""
    for bc in (128, 96, 64, 48, 32, 16, 8):
        if C % bc == 0:
            return min(bc, C)
    return C


def _kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, kv, g, hd, bc, nblocks, scale):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].reshape(kv, g, hd)
    k = k_ref[...]  # [BC, KV, hd]
    v = v_ref[...]  # [BC, KV, hd]
    # scores for this tile: [KV, G, BC]
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    ) * scale
    pos = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bc), 2)
    valid = pos < vl_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]  # [KV, G]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    # masked probabilities — explicit where() so fully-masked tiles contribute
    # exactly zero (exp(NEG_INF - NEG_INF) would otherwise be 1).
    p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)  # [KV, G, BC]
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))), preferred_element_type=jnp.float32
    )  # [KV, G, hd]
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(j == nblocks - 1)
    def _final():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = out.reshape(kv * g, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention(q, k_cache, v_cache, valid_len, *, interpret=True):
    """Single-query GQA attention over a length-masked KV cache.

    Args:
      q:        [H, hd] f32 — current-position query heads (post-RoPE).
      k_cache:  [C, KV, hd] f32 — cached keys (post-RoPE); rows >= valid_len
                are uninitialised and masked out.
      v_cache:  [C, KV, hd] f32 — cached values.
      valid_len: scalar i32 — number of valid cache rows (>= 1).
      interpret: lower via the Pallas interpreter (required for CPU PJRT).

    Returns:
      [H, hd] f32 attention output (pre output-projection).
    """
    H, hd = q.shape
    C, KV, _ = k_cache.shape
    G = H // KV
    bc = _block_c(C)
    nblocks = C // bc
    scale = 1.0 / float(hd) ** 0.5
    vl = jnp.reshape(valid_len, (1,)).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, kv=KV, g=G, hd=hd, bc=bc, nblocks=nblocks, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),  # valid_len (scalar lane)
            pl.BlockSpec((H, hd), lambda j: (0, 0)),  # q: resident
            pl.BlockSpec((bc, KV, hd), lambda j: (j, 0, 0)),  # k tile
            pl.BlockSpec((bc, KV, hd), lambda j: (j, 0, 0)),  # v tile
        ],
        out_specs=pl.BlockSpec((H, hd), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, hd), jnp.float32),
        scratch_shapes=[
            pl.ANY((KV, G), jnp.float32),  # m: running max
            pl.ANY((KV, G), jnp.float32),  # l: running sum
            pl.ANY((KV, G, hd), jnp.float32),  # acc: running output
        ],
        interpret=interpret,
    )(vl, q, k_cache, v_cache)


def vmem_footprint_bytes(C: int, KV: int, H: int, hd: int) -> int:
    """Estimated VMEM-resident bytes per grid step (DESIGN.md §7, L1 target).

    q + one K tile + one V tile + scratch (m, l, acc) + output block, f32.
    """
    bc = _block_c(C)
    G = H // KV
    tiles = 2 * bc * KV * hd  # k + v tile
    scratch = KV * G * (2 + hd)
    return 4 * (H * hd + tiles + scratch + H * hd)
