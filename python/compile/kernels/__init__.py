"""Layer-1 Pallas kernels (build-time only; lowered into the HLO artifacts).

``decode_attention`` — single-query flash-style GQA attention over the KV
cache (the serving hot-spot).  ``hybrid_fields``/``hybrid_scores`` — the
Topological Synapse's hybrid density-coverage landmark sampler (paper §3.3).
``ref`` holds the pure-jnp oracles both are tested against.
"""

from .decode_attention import decode_attention
from .hybrid_scores import hybrid_fields, hybrid_scores
from . import ref

__all__ = ["decode_attention", "hybrid_fields", "hybrid_scores", "ref"]
