"""L1 Pallas kernel: hybrid density-coverage landmark fields (paper §3.3).

This is the compute hot-spot of the Topological Synapse.  The KV cache is
treated as a point cloud in latent space; for every cached position *i* the
kernel produces the two fields the hybrid sampler mixes:

  attn[i] = sum_h softmax_i(q_h . K_i / sqrt(d_k))
            — the paper's "Attention Score Summation" term (§3.3), used as an
              inverse-kernel-density estimate of semantic importance;
  rho[i]  = mean_j exp(-||K_i - K_j||^2 / (2 sigma^2))
            — Gaussian kernel density over the key cloud; LOW density means
              the point covers a geometrically distinct region (the paper's
              "Geometric Coverage" term).

The O(C^2) density term is the expensive part; its pairwise distances are
computed as an MXU-shaped matmul (||a||^2 + ||b||^2 - 2 a.b) per tile pair.

Structure (TPU-thinking, DESIGN.md §8): a two-phase sequential grid
``(2, C/BC)``.  Phase 0 streams K tiles and accumulates global online-softmax
statistics (m, l) in scratch; phase 1 revisits each tile to emit normalised
attention mass and the density row-block against the full key set.  For the
capacities used here (C <= 512) the full key set fits VMEM (<= 64 KB); the
paper-scale variant would add a third grid axis to tile the j-dimension.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic custom-calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _block_c(C: int) -> int:
    for bc in (128, 96, 64, 48, 32, 16, 8):
        if C % bc == 0:
            return min(bc, C)
    return C


def _kernel(vl_ref, sig_ref, q_ref, k_ref, kfull_ref, attn_ref, rho_ref,
            m_ref, l_ref, *, kv, g, hd, bc, nblocks, scale):
    phase = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(jnp.logical_and(phase == 0, j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...].reshape(kv, g, hd)
    k = k_ref[...]  # [BC, KV, hd]
    s = jax.lax.dot_general(
        q, k, (((2,), (2,)), ((0,), (1,))), preferred_element_type=jnp.float32
    ) * scale  # [KV, G, BC]
    pos = j * bc + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bc), 2)
    valid = pos < vl_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    @pl.when(phase == 0)
    def _accumulate():
        # online-softmax statistics over the whole (masked) cache
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        m_ref[...] = m_new

    @pl.when(phase == 1)
    def _emit():
        # attention mass, normalised with the phase-0 global statistics
        p = jnp.where(
            valid,
            jnp.exp(s - m_ref[...][..., None])
            / jnp.maximum(l_ref[...], 1e-30)[..., None],
            0.0,
        )
        attn_ref[...] = p.sum(axis=(0, 1))  # [BC]

        # density row-block: this tile vs the full key cloud
        row = k.reshape(bc, kv * hd)  # [BC, D']
        full = kfull_ref[...].reshape(-1, kv * hd)  # [C, D']
        rsq = jnp.sum(row * row, axis=-1)  # [BC]
        fsq = jnp.sum(full * full, axis=-1)  # [C]
        cross = jax.lax.dot_general(
            row, full, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [BC, C] — the MXU tile
        d2 = jnp.maximum(rsq[:, None] + fsq[None, :] - 2.0 * cross, 0.0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, full.shape[0]), 1)
        ker = jnp.where(cols < vl_ref[0], jnp.exp(-d2 * sig_ref[0]), 0.0)
        denom = jnp.maximum(vl_ref[0].astype(jnp.float32), 1.0)
        rho = ker.sum(axis=-1) / denom  # [BC]
        rowvalid = (j * bc + jax.lax.broadcasted_iota(jnp.int32, (bc,), 0)) < vl_ref[0]
        rho_ref[...] = jnp.where(rowvalid, rho, 1.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def hybrid_fields(q, k_cache, valid_len, inv2sig2, *, interpret=True):
    """Compute the (attn, rho) landmark fields over a length-masked cache.

    Args:
      q:        [H, hd] f32 — the Main Agent's current query heads Q_t.
      k_cache:  [C, KV, hd] f32 — scoring-layer cached keys.
      valid_len: scalar i32 — number of valid cache rows.
      inv2sig2: scalar f32 — Gaussian bandwidth 1/(2 sigma^2).
      interpret: lower via the Pallas interpreter (required for CPU PJRT).

    Returns:
      (attn[C], rho[C]) f32: attention mass (0 on invalid rows) and kernel
      density (1 on invalid rows).
    """
    H, hd = q.shape
    C, KV, _ = k_cache.shape
    G = H // KV
    bc = _block_c(C)
    nblocks = C // bc
    scale = 1.0 / float(hd) ** 0.5
    vl = jnp.reshape(valid_len, (1,)).astype(jnp.int32)
    sg = jnp.reshape(inv2sig2, (1,)).astype(jnp.float32)

    kernel = functools.partial(
        _kernel, kv=KV, g=G, hd=hd, bc=bc, nblocks=nblocks, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(2, nblocks),
        in_specs=[
            pl.BlockSpec((1,), lambda p, j: (0,)),  # valid_len
            pl.BlockSpec((1,), lambda p, j: (0,)),  # inv2sig2
            pl.BlockSpec((H, hd), lambda p, j: (0, 0)),  # q resident
            pl.BlockSpec((bc, KV, hd), lambda p, j: (j, 0, 0)),  # K tile
            pl.BlockSpec((C, KV, hd), lambda p, j: (0, 0, 0)),  # K full (phase 1)
        ],
        out_specs=[
            pl.BlockSpec((bc,), lambda p, j: (j,)),
            pl.BlockSpec((bc,), lambda p, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C,), jnp.float32),
            jax.ShapeDtypeStruct((C,), jnp.float32),
        ],
        scratch_shapes=[
            pl.ANY((KV, G), jnp.float32),  # m
            pl.ANY((KV, G), jnp.float32),  # l
        ],
        interpret=interpret,
    )(vl, sg, q, k_cache, k_cache)


def hybrid_scores(q, k_cache, valid_len, alpha, inv2sig2, *, interpret=True):
    """Full §3.3 hybrid score: normalised mix of the two kernel fields.

    The elementwise epilogue (max-normalisation + alpha-mix) runs in plain
    jnp inside the same jit/HLO module; the O(C^2 + C.H) work is the kernel.
    Invalid rows score NEG_INF so top-k never selects them.
    """
    attn, rho = hybrid_fields(q, k_cache, valid_len, inv2sig2, interpret=interpret)
    C = attn.shape[0]
    mask = jnp.arange(C) < valid_len
    attn_hat = attn / jnp.maximum(jnp.max(jnp.where(mask, attn, 0.0)), 1e-30)
    rho_hat = rho / jnp.maximum(jnp.max(jnp.where(mask, rho, 0.0)), 1e-30)
    score = alpha * attn_hat + (1.0 - alpha) * (1.0 - rho_hat)
    return jnp.where(mask, score, NEG_INF)


def vmem_footprint_bytes(C: int, KV: int, H: int, hd: int) -> int:
    """Estimated peak VMEM bytes per phase-1 grid step (L1 perf target)."""
    bc = _block_c(C)
    G = H // KV
    dflat = KV * hd
    tile = bc * dflat  # K tile
    full = C * dflat  # resident key cloud
    cross = bc * C  # distance tile
    scratch = 2 * KV * G
    return 4 * (H * hd + tile + full + cross + scratch + 2 * bc)
