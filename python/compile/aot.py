"""AOT export: train weights, lower every program to HLO text, emit manifest.

This is the single entry point of the Python build path:

    cd python && python -m compile.aot --out-dir ../artifacts --configs tiny,small

Outputs (per config):
    weights_<cfg>.npz          flat weights, keys ``w000_embed`` ... (ordered ABI)
    <cfg>_<program>.hlo.txt    one HLO-text artifact per exported program
    golden_<cfg>.json          golden vectors for the rust integration tests
plus a global ``manifest.json`` describing configs, capacities, programs,
input/output shapes and FLOP estimates — everything the rust runtime needs.

Incremental: training is skipped when a weights file with a matching
fingerprint already exists; `make artifacts` skips the whole step when
sources are older than the manifest.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import (
    CONFIGS, ANALYTIC_CONFIGS, Capacities, ModelConfig,
    DEFAULT_ALPHA, default_inv2sig2, TRAIN_STEPS, config_fingerprint, BOS_ID,
)
from .hlo import to_hlo_text
from .train import train

SEED = 0


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec_json(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def weight_key(i: int, name: str) -> str:
    return f"w{i:03d}_{name}"


def save_weights(path: str, cfg: ModelConfig, flat) -> None:
    arrays = {
        weight_key(i, name): np.asarray(arr)
        for i, ((name, _), arr) in enumerate(zip(M.param_spec(cfg), flat))
    }
    np.savez(path, **arrays)  # ZIP_STORED: the rust npz reader expects stored


def load_weights(path: str, cfg: ModelConfig):
    with np.load(path) as z:
        keys = sorted(z.files)
        return [jnp.asarray(z[k]) for k in keys]


def decode_flops(cfg: ModelConfig, C: int) -> int:
    """Rough per-token decode FLOPs at full cache: 2*P + attention term."""
    return 2 * cfg.param_count() + 4 * C * cfg.n_heads * cfg.head_dim


def attention_impl() -> bool:
    """Decode-attention implementation selector (True = Pallas kernel).

    ``WARP_ATTENTION=jnp`` flips the decode inner attention to the
    pure-jnp oracle path — identical semantics (pytest asserts allclose),
    ~1.9x faster on the CPU-PJRT substitute because it skips the Pallas
    interpreter's while-loop lowering (EXPERIMENTS.md §Perf L2).  The
    default stays ``pallas``: that is the faithful TPU artifact.
    """
    return os.environ.get("WARP_ATTENTION", "pallas") != "jnp"


def programs_for(cfg: ModelConfig, caps: Capacities):
    """(name, fn, step_arg_specs, out_specs, flops) for every exported program."""
    use_pallas = attention_impl()
    L, KV, hd, D, V = (
        cfg.n_layers, cfg.n_kv_heads, cfg.head_dim, cfg.d_model, cfg.vocab_size,
    )
    S, C, Cs, K, T, B = (
        caps.prefill_len, caps.main_ctx, caps.side_ctx,
        caps.synapse_k, caps.inject_len, caps.decode_batch,
    )
    i32, f32 = jnp.int32, jnp.float32
    cache = lambda c: _sds((L, c, KV, hd))
    progs = [
        (
            f"prefill_s{S}_c{C}",
            M.make_prefill(cfg, S, C),
            [("tokens", (S,), i32), ("length", (), i32)],
            [("logits", (S, V)), ("hidden_last", (D,)),
             ("k_cache", (L, C, KV, hd)), ("v_cache", (L, C, KV, hd))],
            2 * cfg.param_count() * S,
        ),
        # Decode is compiled at a LADDER of cache capacities; the rust engine
        # dispatches each step to the smallest tier that fits the live
        # context, cutting the dominant per-step cost (cache upload + masked
        # attention over dead rows) by up to C/tier (§Perf opt A).
        *[
            (
                f"decode_c{ct}",
                M.make_decode(cfg, ct, use_pallas=use_pallas),
                [("token", (), i32), ("pos", (), i32),
                 ("k_cache", (L, ct, KV, hd), f32),
                 ("v_cache", (L, ct, KV, hd), f32),
                 ("cache_len", (), i32)],
                [("logits", (V,)), ("hidden", (D,)),
                 ("k_new", (L, KV, hd)), ("v_new", (L, KV, hd))],
                decode_flops(cfg, ct),
            )
            for ct in sorted({128, 256, C, Cs})
        ],
        (
            f"decode_batch_b{B}_c{Cs}",
            M.make_decode_batch(cfg, B, Cs, use_pallas=use_pallas),
            [("tokens", (B,), i32), ("pos", (B,), i32),
             ("k_cache", (B, L, Cs, KV, hd), f32),
             ("v_cache", (B, L, Cs, KV, hd), f32),
             ("cache_len", (B,), i32)],
            [("logits", (B, V)), ("hidden", (B, D)),
             ("k_new", (B, L, KV, hd)), ("v_new", (B, L, KV, hd))],
            B * decode_flops(cfg, Cs),
        ),
        (
            f"synapse_extract_c{C}_k{K}",
            M.make_synapse_extract(cfg, C, K, use_pallas=use_pallas),
            [("hidden", (D,), f32),
             ("k_cache", (L, C, KV, hd), f32), ("v_cache", (L, C, KV, hd), f32),
             ("cache_len", (), i32), ("alpha", (), f32), ("inv2sig2", (), f32)],
            [("lm_k", (L, K, KV, hd)), ("lm_v", (L, K, KV, hd)),
             ("indices", (K,)), ("sel_scores", (K,))],  # indices f32 (see model.py)
            2 * C * C * KV * hd + 2 * C * cfg.n_heads * hd,
        ),
        (
            f"inject_encode_t{T}",
            M.make_inject_encode(cfg, T),
            [("tokens", (T,), i32), ("length", (), i32), ("pos_base", (), i32)],
            [("k", (L, T, KV, hd)), ("v", (L, T, KV, hd)), ("hidden_last", (D,))],
            2 * cfg.param_count() * T,
        ),
    ]
    return progs


def lower_program(cfg, flat_specs, fn, step_specs) -> str:
    args = [tuple(flat_specs)]
    for name, shape, *rest in step_specs:
        dtype = rest[0] if rest else jnp.float32
        args.append(_sds(shape, dtype))
    # keep_unused: every program keeps the FULL weights tuple in its HLO
    # signature even if it only reads part of it (e.g. synapse_extract only
    # needs the scoring layer's Wq) — the rust runtime passes the same
    # resident weight buffers (the Prism) to every program.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    return to_hlo_text(lowered)


def make_goldens(cfg: ModelConfig, caps: Capacities, flat) -> dict:
    """Golden vectors for rust integration tests (integration_runtime.rs)."""
    S, C, K = caps.prefill_len, caps.main_ctx, caps.synapse_k
    prompt = (
        b"user: tell me about the kv cache.\n"
        b"river: the cache grows one row per token. the synapse "
        b"selects landmark tokens.\nriver: "
    )  # > synapse_k tokens (and < S) so the golden extraction is well-posed
    toks = [BOS_ID] + list(prompt)
    padded = np.full((S,), 256, np.int32)  # PAD
    padded[: len(toks)] = toks
    length = len(toks)

    prefill = M.make_prefill(cfg, S, C)
    logits, hidden_last, kc, vc = prefill(flat, jnp.asarray(padded), jnp.int32(length))

    decode = M.make_decode(cfg, C)
    steps = []
    cl = length
    tok = int(jnp.argmax(logits[length - 1]))
    kc_h, vc_h = kc, vc
    for _ in range(4):
        lg, hid, kn, vn = decode(
            flat, jnp.int32(tok), jnp.int32(cl), kc_h, vc_h, jnp.int32(cl)
        )
        kc_h = kc_h.at[:, cl].set(kn)
        vc_h = vc_h.at[:, cl].set(vn)
        steps.append({
            "token_in": tok,
            "pos": cl,
            "argmax": int(jnp.argmax(lg)),
            "logits8": np.asarray(lg[:8]).tolist(),
            "hidden4": np.asarray(hid[:4]).tolist(),
        })
        tok = int(jnp.argmax(lg))
        cl += 1

    extract = M.make_synapse_extract(cfg, C, K)
    lm_k, lm_v, idx, vals = extract(
        flat, hidden_last, kc, vc, jnp.int32(length),
        jnp.float32(DEFAULT_ALPHA), jnp.float32(default_inv2sig2(cfg)),
    )

    inject = M.make_inject_encode(cfg, caps.inject_len)
    itoks = np.full((caps.inject_len,), 256, np.int32)
    thought = b"fact: a kilobyte"
    itoks[: len(thought)] = list(thought)
    ik, iv, ih = inject(flat, jnp.asarray(itoks), jnp.int32(len(thought)), jnp.int32(77))

    return {
        "prompt_tokens": [int(t) for t in toks],
        "prefill": {
            "length": length,
            "argmax_last": int(jnp.argmax(logits[length - 1])),
            "logits8_last": np.asarray(logits[length - 1, :8]).tolist(),
            "hidden8": np.asarray(hidden_last[:8]).tolist(),
        },
        "decode_steps": steps,
        "synapse": {
            "cache_len": length,
            "alpha": DEFAULT_ALPHA,
            "inv2sig2": default_inv2sig2(cfg),
            "indices": np.asarray(idx).tolist(),
            "scores8": np.asarray(vals[:8]).tolist(),
            "lm_k_slice": np.asarray(lm_k[0, 0, 0, :4]).tolist(),
        },
        "inject": {
            "tokens": itoks.tolist(),
            "length": int(len(thought)),
            "pos_base": 77,
            "k_slice": np.asarray(ik[0, 0, 0, :4]).tolist(),
            "hidden4": np.asarray(ih[:4]).tolist(),
        },
    }


def build_config(cfg: ModelConfig, caps: Capacities, out_dir: str, steps: int) -> dict:
    fp = config_fingerprint(cfg, caps, steps, SEED)
    wpath = os.path.join(out_dir, f"weights_{cfg.name}.npz")
    fp_path = wpath + ".fingerprint"

    if os.path.exists(wpath) and os.path.exists(fp_path) and \
            open(fp_path).read().strip() == fp:
        print(f"[aot:{cfg.name}] weights up-to-date ({fp}), skipping training")
        flat = load_weights(wpath, cfg)
    else:
        print(f"[aot:{cfg.name}] training {steps} steps ...")
        params = train(cfg, steps, seed=SEED)
        flat = M.flatten_params(cfg, params)
        save_weights(wpath, cfg, flat)
        with open(fp_path, "w") as f:
            f.write(fp)

    flat_specs = [_sds(a.shape, a.dtype) for a in flat]
    weight_specs = [
        _spec_json(weight_key(i, name), shape, "f32")
        for i, (name, shape) in enumerate(M.param_spec(cfg))
    ]

    artifacts = []
    for name, fn, step_specs, out_specs, flops in programs_for(cfg, caps):
        fname = f"{cfg.name}_{name}.hlo.txt"
        t0 = time.time()
        text = lower_program(cfg, flat_specs, fn, step_specs)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"[aot:{cfg.name}] {fname}: {len(text)/1e3:.0f} kB "
              f"({time.time()-t0:.1f}s)")
        artifacts.append({
            "name": f"{cfg.name}_{name}",
            "program": name,
            "config": cfg.name,
            "file": fname,
            "inputs": [
                _spec_json(n, s, "s32" if d == jnp.int32 else "f32")
                for n, s, *rest in step_specs
                for d in [rest[0] if rest else jnp.float32]
            ],
            "outputs": [
                _spec_json(o[0], o[1], o[2] if len(o) > 2 else "f32")
                for o in out_specs
            ],
            "flops": int(flops),
        })

    gpath = os.path.join(out_dir, f"golden_{cfg.name}.json")
    with open(gpath, "w") as f:
        json.dump(make_goldens(cfg, caps, flat), f, indent=1)

    return {
        "model": cfg.to_json(),
        "capacities": caps.to_json(),
        "weights_file": os.path.basename(wpath),
        "weight_params": weight_specs,
        "golden_file": os.path.basename(gpath),
        "fingerprint": fp,
        "defaults": {
            "alpha": DEFAULT_ALPHA,
            "inv2sig2": default_inv2sig2(cfg),
            "gate_theta": 0.5,
        },
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--steps", type=int, default=None,
                    help="override training steps (all configs)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    env_steps = os.environ.get("WARP_TRAIN_STEPS")
    manifest = {"version": 1, "configs": {}, "analytic_configs": {}}
    for name in args.configs.split(","):
        cfg = CONFIGS[name.strip()]
        caps = Capacities()
        steps = args.steps or (int(env_steps) if env_steps else TRAIN_STEPS[cfg.name])
        manifest["configs"][cfg.name] = build_config(cfg, caps, args.out_dir, steps)
    for name, cfg in ANALYTIC_CONFIGS.items():
        manifest["analytic_configs"][name] = cfg.to_json()

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}")


if __name__ == "__main__":
    main()
