"""§Perf L1/L2 structural report (EXPERIMENTS.md §Perf).

Measures, on the CPU substitute:
  * L2: jitted decode step wallclock, Pallas-interpret vs plain-jnp inner
    attention (identical semantics — pytest asserts allclose);
  * L2: lowered-HLO size/op-count per variant (fusion sanity);
  * L1: VMEM footprint estimates of both Pallas kernels across scales,
    including the paper-scale Qwen-0.5B geometry (interpret mode gives no
    TPU wallclock — these are the structural numbers DESIGN.md §7 calls for).

Usage: cd python && python -m compile.perf_report
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from . import model as M
from .configs import TINY, SMALL
from .hlo import to_hlo_text
from .kernels.decode_attention import vmem_footprint_bytes as da_vmem, _block_c
from .kernels.hybrid_scores import vmem_footprint_bytes as hs_vmem


def time_decode(cfg, C, use_pallas, iters=50):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    flat = tuple(M.flatten_params(cfg, params))
    kc = jnp.zeros((cfg.n_layers, C, cfg.n_kv_heads, cfg.head_dim))
    args = (flat, jnp.int32(65), jnp.int32(100), kc, jnp.zeros_like(kc), jnp.int32(100))
    fn = jax.jit(M.make_decode(cfg, C, use_pallas=use_pallas), keep_unused=True)
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    dt = (time.perf_counter() - t0) / iters
    text = to_hlo_text(
        jax.jit(M.make_decode(cfg, C, use_pallas=use_pallas), keep_unused=True).lower(*args)
    )
    return dt, len(text), text.count("\n")


def main() -> None:
    print("═══ §Perf L2: decode step, Pallas-interpret vs plain-jnp (CPU) ═══\n")
    print(f"{'config':<8} {'impl':<18} {'µs/step':>10} {'HLO kB':>8} {'HLO lines':>10}")
    for cfg in (TINY, SMALL):
        for name, up in [("pallas-interpret", True), ("plain-jnp", False)]:
            dt, size, lines = time_decode(cfg, 512, up)
            print(f"{cfg.name:<8} {name:<18} {dt*1e6:>10.1f} {size/1e3:>8.0f} {lines:>10}")

    print("\n═══ §Perf L1: Pallas kernel VMEM footprints (structural) ═══\n")
    print(f"{'geometry':<24} {'BC':>5} {'decode_attn':>14} {'hybrid_fields':>14}")
    for tag, C, KV, H, hd in [
        ("tiny   C=512", 512, 2, 4, 16),
        ("small  C=512", 512, 2, 8, 16),
        ("qwen.5 C=4096", 4096, 2, 14, 64),
        ("qwen.5 C=32768", 32768, 2, 14, 64),
    ]:
        da = da_vmem(C, KV, H, hd)
        hs = hs_vmem(C, KV, H, hd)
        print(
            f"{tag:<24} {_block_c(C):>5} {da/1024:>11.1f} KiB {hs/1024/1024:>10.2f} MiB"
        )
    print(
        "\nnotes: decode_attention stays VMEM-resident at every scale "
        "(online-softmax tiles).  hybrid_fields keeps the full key cloud "
        "resident — fine to C≈4k (≤4 MiB), beyond that the j-dimension "
        "needs a third grid axis (DESIGN.md §8); at the paper's 32k context "
        "the dominant term is the BCxC distance tile (16 MiB)."
    )


if __name__ == "__main__":
    main()
