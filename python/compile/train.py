"""Build-time training of the from-scratch byte-LM (DESIGN.md §4).

A few hundred Adam steps of next-byte prediction on the synthetic
agent-council corpus, so the served model produces structured text (including
``[TASK: ...]`` router triggers) instead of noise.  Fully deterministic:
seeded corpus, seeded init, seeded batch sampling.

Runs with the plain-jnp attention path (no Pallas in the training loop); the
pytest suite separately asserts that the jnp and Pallas decode paths agree on
the *trained* weights.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .configs import ModelConfig, BOS_ID
from .corpus import build_corpus

SEQ_LEN = 128
BATCH = 16
PEAK_LR = 3e-3
WARMUP = 40


def sample_batch(data: np.ndarray, rng: np.random.Generator, batch: int, seq: int):
    """Random corpus windows, each prefixed with BOS."""
    starts = rng.integers(0, len(data) - seq, size=batch)
    toks = np.stack([
        np.concatenate([[BOS_ID], data[s : s + seq - 1]]) for s in starts
    ]).astype(np.int32)
    lengths = np.full((batch,), seq, np.int32)
    return jnp.asarray(toks), jnp.asarray(lengths)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return zeros, jax.tree.map(jnp.zeros_like, params)


def train(cfg: ModelConfig, steps: int, seed: int = 0, log_every: int = 50,
          corpus_seed: int = 7) -> M.Params:
    """Train and return Params.  ~10-40 ms/step on CPU for tiny/small."""
    data = np.frombuffer(build_corpus(seed=corpus_seed), dtype=np.uint8)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    m, v = adam_init(params)
    rng = np.random.default_rng(seed + 1)

    b1, b2, eps = 0.9, 0.95, 1e-8

    @jax.jit
    def step_fn(params, m, v, toks, lens, t):
        loss, grads = jax.value_and_grad(
            lambda p: M.batched_lm_loss(cfg, p, toks, lens)
        )(params)
        lr = PEAK_LR * jnp.minimum(1.0, t / WARMUP) * (
            0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(t / steps, 1.0)))
        )
        m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
        v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
        tt = t + 1.0
        params = jax.tree.map(
            lambda p, mi, vi: p
            - lr * (mi / (1 - b1 ** tt)) / (jnp.sqrt(vi / (1 - b2 ** tt)) + eps),
            params, m, v,
        )
        return params, m, v, loss

    t0 = time.time()
    for t in range(steps):
        toks, lens = sample_batch(data, rng, BATCH, SEQ_LEN)
        params, m, v, loss = step_fn(params, m, v, toks, lens, jnp.float32(t))
        if t % log_every == 0 or t == steps - 1:
            print(
                f"[train:{cfg.name}] step {t:4d}/{steps} "
                f"loss {float(loss):.4f}  ({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params
