"""Layer-2 JAX model: a Qwen2-style decoder-only transformer, from scratch.

Architecture (matching the paper's Qwen2.5-0.5B testbed one-for-one in
structure, scaled down per DESIGN.md §4): RMSNorm → GQA attention with RoPE
→ residual → RMSNorm → SwiGLU MLP → residual; tied byte-level LM head.

The decode path calls the Layer-1 Pallas ``decode_attention`` kernel; the
synapse path calls the Layer-1 ``hybrid_scores`` kernel.  Both lower (with
``interpret=True``) into the same HLO module exported by ``aot.py``.

ABI note (DESIGN.md §2): every exported program takes the weights as a flat
*tuple of arrays* in ``param_spec`` order, so the rust side can load
``weights_<cfg>.npz`` (keys ``w000_...``, sorted) and pass them as leading
PJRT buffers — uploaded once, shared by every agent: this is the paper's
Prism / Singleton Weight Sharing made literal.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig, Capacities
from .kernels.decode_attention import decode_attention
from .kernels.hybrid_scores import hybrid_scores
from .kernels import ref as kref


# ── Parameter layout ────────────────────────────────────────────────────────

def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat weight ABI."""
    d, hd = cfg.d_model, cfg.head_dim
    spec: list[tuple[str, tuple[int, ...]]] = [("embed", (cfg.vocab_size, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"l{i}_ln1", (d,)),
            (f"l{i}_wq", (d, cfg.n_heads * hd)),
            (f"l{i}_wk", (d, cfg.n_kv_heads * hd)),
            (f"l{i}_wv", (d, cfg.n_kv_heads * hd)),
            (f"l{i}_wo", (cfg.n_heads * hd, d)),
            (f"l{i}_ln2", (d,)),
            (f"l{i}_wg", (d, cfg.d_ff)),
            (f"l{i}_wu", (d, cfg.d_ff)),
            (f"l{i}_wd", (cfg.d_ff, d)),
        ]
    spec.append(("ln_f", (d,)))
    return spec


class Layer(NamedTuple):
    ln1: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln2: jax.Array
    wg: jax.Array
    wu: jax.Array
    wd: jax.Array


class Params(NamedTuple):
    embed: jax.Array
    layers: tuple[Layer, ...]
    ln_f: jax.Array


def pack_params(cfg: ModelConfig, flat) -> Params:
    """Rebuild the structured view from the flat ABI tuple."""
    spec = param_spec(cfg)
    assert len(flat) == len(spec), (len(flat), len(spec))
    by_name = {name: arr for (name, _), arr in zip(spec, flat)}
    layers = tuple(
        Layer(*(by_name[f"l{i}_{f}"] for f in Layer._fields))
        for i in range(cfg.n_layers)
    )
    return Params(embed=by_name["embed"], layers=layers, ln_f=by_name["ln_f"])


def flatten_params(cfg: ModelConfig, params: Params) -> list[jax.Array]:
    out = [params.embed]
    for layer in params.layers:
        out.extend(layer)
    out.append(params.ln_f)
    return out


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Scaled-Gaussian init (std 0.02, output projections down-scaled)."""
    spec = param_spec(cfg)
    flat = []
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")) or name == "ln_f":
            flat.append(jnp.ones(shape, jnp.float32))
        else:
            std = 0.02
            if name.endswith(("wo", "wd")):
                std = 0.02 / (2.0 * cfg.n_layers) ** 0.5
            flat.append(std * jax.random.normal(sub, shape, jnp.float32))
    return pack_params(cfg, flat)


# ── Primitive blocks ────────────────────────────────────────────────────────

def rms_norm(x, scale, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def rope_cos_sin(cfg: ModelConfig, positions):
    """RoPE angle tables for integer positions.  positions: [...]."""
    hd = cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., hd/2]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """Rotate pairs (llama half-split convention).  x: [..., hd]."""
    hd = x.shape[-1]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(h, layer: Layer):
    return (jax.nn.silu(h @ layer.wg) * (h @ layer.wu)) @ layer.wd


# ── Prefill (sequence) path — plain jnp attention ───────────────────────────

def _seq_attention(q, k, v, mask, cfg: ModelConfig):
    """Masked GQA attention over a full sequence.  q:[S,H,hd] k,v:[S,KV,hd]."""
    S = q.shape[0]
    KV, G = cfg.n_kv_heads, cfg.gqa_groups
    qg = q.reshape(S, KV, G, cfg.head_dim)
    s = jnp.einsum("ikgd,jkd->kgij", qg, k) / (cfg.head_dim ** 0.5)
    s = jnp.where(mask[None, None, :, :], s, kref.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None, :, :], p, 0.0)
    out = jnp.einsum("kgij,jkd->ikgd", p, v)
    return out.reshape(S, cfg.n_heads * cfg.head_dim)


def forward_sequence(cfg: ModelConfig, params: Params, tokens, positions, length):
    """Causal forward pass over a (padded) token sequence.

    Args:
      tokens:    [S] i32, padded with PAD beyond ``length``.
      positions: [S] i32 RoPE positions (prefill: arange; injection: offset).
      length:    scalar i32 count of real tokens.

    Returns:
      (hidden[S, D] final-layer normed states, k[L, S, KV, hd], v[L, S, KV, hd])
    """
    S = tokens.shape[0]
    x = params.embed[tokens]  # [S, D]
    cos, sin = rope_cos_sin(cfg, positions)  # [S, hd/2]
    idx = jnp.arange(S)
    causal = idx[None, :] <= idx[:, None]
    valid = idx[None, :] < length
    mask = causal & valid
    ks, vs = [], []
    for layer in params.layers:
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        q = (h @ layer.wq).reshape(S, cfg.n_heads, cfg.head_dim)
        k = (h @ layer.wk).reshape(S, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ layer.wv).reshape(S, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])
        x = x + _seq_attention(q, k, v, mask, cfg) @ layer.wo
        h = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + swiglu(h, layer)
        ks.append(k)
        vs.append(v)
    hidden = rms_norm(x, params.ln_f, cfg.norm_eps)
    return hidden, jnp.stack(ks), jnp.stack(vs)


# ── Exported programs ───────────────────────────────────────────────────────
# Each ``make_*`` returns a function over (flat_params, *step_args) that
# aot.py jits and lowers to one HLO artifact.

def make_prefill(cfg: ModelConfig, S: int, C: int):
    """prefill_s{S}_c{C}: prompt → logits + KV cache (in capacity-C layout).

    (tokens[S] i32, length i32) →
      (logits[S, V], hidden_last[D], k_cache[L, C, KV, hd], v_cache[...])
    """

    def prefill(flat, tokens, length):
        params = pack_params(cfg, flat)
        positions = jnp.arange(S, dtype=jnp.int32)
        hidden, ks, vs = forward_sequence(cfg, params, tokens, positions, length)
        logits = hidden @ params.embed.T
        hidden_last = hidden[jnp.clip(length - 1, 0, S - 1)]
        pad = [(0, 0), (0, C - S), (0, 0), (0, 0)]
        return logits, hidden_last, jnp.pad(ks, pad), jnp.pad(vs, pad)

    return prefill


def make_inject_encode(cfg: ModelConfig, T: int):
    """inject_encode_t{T}: Referential-Injection reference pass (§3.6).

    Runs the thought tokens through the model *at virtual RoPE positions*
    ``pos_base + i`` and returns only the resulting K/V entries (plus the
    last hidden state, which the Validation Gate may score).  The rust side
    appends these rows to the Main Agent's cache: the agent "remembers" the
    thought without any visible-stream tokens.

    (tokens[T] i32, length i32, pos_base i32) →
      (k[L, T, KV, hd], v[L, T, KV, hd], hidden_last[D])
    """

    def inject_encode(flat, tokens, length, pos_base):
        params = pack_params(cfg, flat)
        positions = pos_base + jnp.arange(T, dtype=jnp.int32)
        hidden, ks, vs = forward_sequence(cfg, params, tokens, positions, length)
        hidden_last = hidden[jnp.clip(length - 1, 0, T - 1)]
        return ks, vs, hidden_last

    return inject_encode


def decode_step(cfg: ModelConfig, params: Params, token, pos, k_cache, v_cache,
                cache_len, *, use_pallas=True):
    """One decode step over capacity-C caches.

    The new token's K/V rows are written at ``cache_len`` (the caller then
    treats the cache as holding ``cache_len + 1`` rows).  Attention runs over
    the updated cache via the Layer-1 Pallas kernel.

    Returns (logits[V], hidden[D], k_new[L, KV, hd], v_new[L, KV, hd]).
    """
    x = params.embed[token]  # [D]
    cos, sin = rope_cos_sin(cfg, pos)  # [hd/2]
    k_news, v_news = [], []
    for li, layer in enumerate(params.layers):
        h = rms_norm(x, layer.ln1, cfg.norm_eps)
        q = (h @ layer.wq).reshape(cfg.n_heads, cfg.head_dim)
        k_new = (h @ layer.wk).reshape(cfg.n_kv_heads, cfg.head_dim)
        v_new = (h @ layer.wv).reshape(cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, cos[None, :], sin[None, :])
        k_new = apply_rope(k_new, cos[None, :], sin[None, :])
        kc = jax.lax.dynamic_update_slice(k_cache[li], k_new[None], (cache_len, 0, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[li], v_new[None], (cache_len, 0, 0))
        if use_pallas:
            attn = decode_attention(q, kc, vc, cache_len + 1)
        else:
            attn = kref.decode_attention_ref(q, kc, vc, cache_len + 1)
        x = x + attn.reshape(-1) @ layer.wo
        h = rms_norm(x, layer.ln2, cfg.norm_eps)
        x = x + swiglu(h, layer)
        k_news.append(k_new)
        v_news.append(v_new)
    hidden = rms_norm(x, params.ln_f, cfg.norm_eps)
    logits = hidden @ params.embed.T
    return logits, hidden, jnp.stack(k_news), jnp.stack(v_news)


def make_decode(cfg: ModelConfig, C: int, *, use_pallas=True):
    """decode_c{C}: one-token decode.

    (token i32, pos i32, k_cache[L,C,KV,hd], v_cache[...], cache_len i32) →
      (logits[V], hidden[D], k_new[L,KV,hd], v_new[L,KV,hd])
    """

    def decode(flat, token, pos, k_cache, v_cache, cache_len):
        params = pack_params(cfg, flat)
        return decode_step(cfg, params, token, pos, k_cache, v_cache,
                           cache_len, use_pallas=use_pallas)

    return decode


def make_decode_batch(cfg: ModelConfig, B: int, C: int, *, use_pallas=True):
    """decode_batch_b{B}_c{C}: the dynamic batcher's target (vmapped decode).

    (tokens[B] i32, pos[B] i32, k_cache[B,L,C,KV,hd], v_cache[...],
     cache_len[B] i32) →
      (logits[B,V], hidden[B,D], k_new[B,L,KV,hd], v_new[B,L,KV,hd])
    """

    def one(flat, token, pos, k_cache, v_cache, cache_len):
        params = pack_params(cfg, flat)
        return decode_step(cfg, params, token, pos, k_cache, v_cache,
                           cache_len, use_pallas=use_pallas)

    def batch(flat, tokens, pos, k_caches, v_caches, cache_lens):
        return jax.vmap(one, in_axes=(None, 0, 0, 0, 0, 0))(
            flat, tokens, pos, k_caches, v_caches, cache_lens
        )

    return batch


def make_synapse_extract(cfg: ModelConfig, C: int, K: int, *, use_pallas=True,
                         scoring_layer: int | None = None):
    """synapse_extract_c{C}_k{K}: the Topological Synapse sampler (§3.3).

    Scores every cached position with the hybrid density-coverage kernel
    (driven by the Main Agent's current query state, derived from its last
    hidden state via the scoring layer's Wq), selects the top-K landmarks,
    re-sorts them into temporal order, and gathers their K/V rows across
    *all* layers into a side-agent-shaped landmark cache.

    (hidden[D], k_cache[L,C,KV,hd], v_cache[...], cache_len i32,
     alpha f32, inv2sig2 f32) →
      (lm_k[L,K,KV,hd], lm_v[L,K,KV,hd], indices[K] i32, sel_scores[K] f32)
    """
    sl = cfg.n_layers - 1 if scoring_layer is None else scoring_layer

    def extract(flat, hidden, k_cache, v_cache, cache_len, alpha, inv2sig2):
        params = pack_params(cfg, flat)
        layer = params.layers[sl]
        q = (hidden @ layer.wq).reshape(cfg.n_heads, cfg.head_dim)
        cos, sin = rope_cos_sin(cfg, cache_len)
        q = apply_rope(q, cos[None, :], sin[None, :])
        if use_pallas:
            scores = hybrid_scores(q, k_cache[sl], cache_len, alpha, inv2sig2)
        else:
            scores = kref.hybrid_scores_ref(q, k_cache[sl], cache_len, alpha, inv2sig2)
        # NOTE: not lax.top_k — it lowers to the `topk` HLO op, which the
        # xla_extension 0.5.1 text parser (behind the rust `xla` crate)
        # rejects.  argsort lowers to plain `sort` and round-trips.
        order_desc = jnp.argsort(-scores)
        idx = order_desc[:K]
        vals = scores[idx]
        # clamp (cache_len < K never happens in the runtime, but stay safe)
        idx = jnp.minimum(idx, jnp.maximum(cache_len - 1, 0))
        # temporal re-sort: landmarks keep their original RoPE positions, so
        # the side agent sees them in causal order.
        order = jnp.argsort(idx)
        idx = idx[order].astype(jnp.int32)
        vals = vals[order]
        lm_k = jnp.take(k_cache, idx, axis=1)  # [L, K, KV, hd]
        lm_v = jnp.take(v_cache, idx, axis=1)
        # indices returned as f32: readback of mixed f32/s32 output tuples
        # segfaults in xla_extension 0.5.1 (runtime converts back to i32;
        # exact for idx < 2^24).
        return lm_k, lm_v, idx.astype(jnp.float32), vals

    return extract


# ── Training-path loss (plain-jnp attention; used by train.py) ─────────────

def lm_loss(cfg: ModelConfig, params: Params, tokens, length):
    """Next-byte cross-entropy over one padded sequence.  tokens: [S] i32."""
    S = tokens.shape[0]
    positions = jnp.arange(S, dtype=jnp.int32)
    hidden, _, _ = forward_sequence(cfg, params, tokens, positions, length)
    logits = hidden @ params.embed.T  # [S, V]
    targets = jnp.roll(tokens, -1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)[:, 0]
    mask = (jnp.arange(S) < length - 1).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def batched_lm_loss(cfg: ModelConfig, params: Params, tokens, lengths):
    per = jax.vmap(lambda t, l: lm_loss(cfg, params, t, l))(tokens, lengths)
    return jnp.mean(per)
