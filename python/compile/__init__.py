"""Warp-Cortex build path: JAX model (L2) + Pallas kernels (L1) + AOT export.

Everything in this package runs ONCE at build time (`make artifacts`); the
rust coordinator (L3) loads the resulting HLO-text artifacts via PJRT and
Python never appears on the request path.
"""
