"""HLO-text lowering helper (the AOT interchange format).

HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 rust crate) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.
"""

from __future__ import annotations

from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """jax ``Lowered`` → XLA HLO text with a tuple root (rust: to_tuple())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()
