"""AOT export consistency: the built artifacts/ tree must exist, be
internally consistent (manifest ↔ files ↔ weights ABI ↔ goldens), and the
HLO text must avoid constructs xla_extension 0.5.1 rejects."""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.configs import CONFIGS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_configs(manifest):
    assert set(manifest["configs"]) == set(CONFIGS)
    assert "qwen2_5_0_5b" in manifest["analytic_configs"]


def test_artifact_files_exist(manifest):
    for cfg in manifest["configs"].values():
        assert os.path.exists(os.path.join(ART, cfg["weights_file"]))
        assert os.path.exists(os.path.join(ART, cfg["golden_file"]))
        for a in cfg["artifacts"]:
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), a["file"]
            assert os.path.getsize(path) > 1000


def test_weights_match_param_spec(manifest):
    for name, cfg in manifest["configs"].items():
        spec = M.param_spec(CONFIGS[name])
        with np.load(os.path.join(ART, cfg["weights_file"])) as z:
            keys = sorted(z.files)
            assert len(keys) == len(spec)
            for key, (pname, shape) in zip(keys, spec):
                assert key.endswith(pname), (key, pname)
                assert z[key].shape == shape
                assert z[key].dtype == np.float32
        assert len(cfg["weight_params"]) == len(spec)


def test_no_unparseable_hlo_ops(manifest):
    """Guards the 0.5.1-parser constraints: no `topk` op (lax.top_k) and no
    mixed-dtype output tuples (readback segfault) — see DESIGN.md §4."""
    for cfg in manifest["configs"].values():
        for a in cfg["artifacts"]:
            text = open(os.path.join(ART, a["file"])).read()
            assert " topk(" not in text, f"{a['file']} uses topk"
            out_dtypes = {o["dtype"] for o in a["outputs"]}
            assert out_dtypes == {"f32"}, (a["name"], out_dtypes)


def test_golden_structure(manifest):
    for cfg in manifest["configs"].values():
        with open(os.path.join(ART, cfg["golden_file"])) as f:
            g = json.load(f)
        k = cfg["capacities"]["synapse_k"]
        assert len(g["prompt_tokens"]) >= k, "golden prompt shorter than K"
        assert len(g["decode_steps"]) >= 4
        idx = g["synapse"]["indices"]
        assert len(idx) == k
        assert all(idx[i] < idx[i + 1] for i in range(len(idx) - 1))
        assert all(0 <= i < g["synapse"]["cache_len"] for i in idx)


def test_capacities_consistent(manifest):
    for cfg in manifest["configs"].values():
        caps = cfg["capacities"]
        assert caps["synapse_k"] < caps["side_ctx"] <= caps["main_ctx"]
        assert caps["prefill_len"] <= caps["main_ctx"]
        assert caps["inject_len"] <= caps["side_ctx"]


def test_flops_positive(manifest):
    for cfg in manifest["configs"].values():
        for a in cfg["artifacts"]:
            assert a["flops"] > 0
