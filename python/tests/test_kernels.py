"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

hypothesis sweeps the shape/dtype space; every case asserts allclose between
the interpret-mode Pallas kernel and `kernels/ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention, _block_c
from compile.kernels.hybrid_scores import hybrid_fields, hybrid_scores
from compile.kernels import ref

# Shapes: (H, KV, hd, C) — GQA ratios 1, 2 and 4; capacities that exercise
# both single-tile and multi-tile grids.
SHAPES = st.sampled_from([
    (4, 2, 16, 64),
    (4, 2, 16, 512),
    (8, 2, 16, 96),
    (8, 4, 8, 128),
    (2, 2, 4, 32),
    (4, 1, 8, 48),
    (14, 2, 64, 128),  # qwen-0.5b head geometry
])


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


class TestDecodeAttention:
    @settings(max_examples=20, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1),
           frac=st.floats(0.01, 1.0))
    def test_matches_ref(self, shape, seed, frac):
        H, KV, hd, C = shape
        key = jax.random.PRNGKey(seed)
        q = rand(jax.random.fold_in(key, 0), (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        v = rand(jax.random.fold_in(key, 2), (C, KV, hd))
        vl = max(1, int(frac * C))
        out = decode_attention(q, k, v, jnp.int32(vl))
        expect = ref.decode_attention_ref(q, k, v, jnp.int32(vl))
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=2e-5)

    def test_single_valid_row_returns_its_value(self):
        # With one valid row, attention must return exactly V[0].
        H, KV, hd, C = 4, 2, 16, 64
        key = jax.random.PRNGKey(0)
        q = rand(key, (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        v = rand(jax.random.fold_in(key, 2), (C, KV, hd))
        out = np.asarray(decode_attention(q, k, v, jnp.int32(1)))
        G = H // KV
        for h in range(H):
            np.testing.assert_allclose(out[h], v[0, h // G], rtol=1e-5, atol=1e-6)

    def test_junk_beyond_valid_len_is_ignored(self):
        H, KV, hd, C = 4, 2, 16, 64
        key = jax.random.PRNGKey(1)
        q = rand(key, (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        v = rand(jax.random.fold_in(key, 2), (C, KV, hd))
        vl = 17
        out1 = decode_attention(q, k, v, jnp.int32(vl))
        # poison the invalid region
        k2 = k.at[vl:].set(1e6)
        v2 = v.at[vl:].set(-1e6)
        out2 = decode_attention(q, k2, v2, jnp.int32(vl))
        np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)

    def test_uniform_scores_average_values(self):
        # identical keys => uniform attention => output = mean of values
        H, KV, hd, C = 2, 2, 8, 32
        key = jax.random.PRNGKey(2)
        k = jnp.broadcast_to(rand(key, (1, KV, hd)), (C, KV, hd))
        v = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        q = rand(jax.random.fold_in(key, 2), (H, hd))
        vl = 20
        out = np.asarray(decode_attention(q, k, v, jnp.int32(vl)))
        expect = np.asarray(v[:vl].mean(axis=0))
        for h in range(H):
            np.testing.assert_allclose(out[h], expect[h], rtol=1e-4, atol=1e-5)

    def test_block_c_divides(self):
        for C in (8, 16, 32, 48, 64, 96, 128, 256, 512):
            assert C % _block_c(C) == 0
            assert _block_c(C) <= 128


class TestHybridScores:
    @settings(max_examples=15, deadline=None)
    @given(shape=SHAPES, seed=st.integers(0, 2**31 - 1),
           frac=st.floats(0.02, 1.0), sig=st.floats(0.001, 0.5))
    def test_fields_match_ref(self, shape, seed, frac, sig):
        H, KV, hd, C = shape
        key = jax.random.PRNGKey(seed)
        q = rand(jax.random.fold_in(key, 0), (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        vl = max(1, int(frac * C))
        a, r = hybrid_fields(q, k, jnp.int32(vl), jnp.float32(sig))
        ae, re_ = ref.hybrid_fields_ref(q, k, jnp.int32(vl), jnp.float32(sig))
        np.testing.assert_allclose(a, ae, rtol=1e-4, atol=2e-5)
        np.testing.assert_allclose(r, re_, rtol=1e-4, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), alpha=st.floats(0.0, 1.0))
    def test_scores_match_ref(self, seed, alpha):
        H, KV, hd, C = 4, 2, 16, 128
        key = jax.random.PRNGKey(seed)
        q = rand(jax.random.fold_in(key, 0), (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        vl = 100
        s = hybrid_scores(q, k, jnp.int32(vl), jnp.float32(alpha), jnp.float32(0.02))
        se = ref.hybrid_scores_ref(q, k, jnp.int32(vl), jnp.float32(alpha), jnp.float32(0.02))
        mask = np.arange(C) < vl
        np.testing.assert_allclose(
            np.asarray(s)[mask], np.asarray(se)[mask], rtol=1e-4, atol=3e-5
        )

    def test_attention_mass_sums_to_num_heads(self):
        # sum_i A_i == H over valid positions (softmax rows sum to 1 per head)
        H, KV, hd, C = 4, 2, 16, 128
        key = jax.random.PRNGKey(5)
        q = rand(key, (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        a, _ = hybrid_fields(q, k, jnp.int32(77), jnp.float32(0.02))
        assert abs(float(a.sum()) - H) < 1e-3

    def test_invalid_rows_never_win(self):
        H, KV, hd, C = 4, 2, 16, 64
        key = jax.random.PRNGKey(6)
        q = rand(key, (H, hd))
        k = rand(jax.random.fold_in(key, 1), (C, KV, hd))
        vl = 10
        s = np.asarray(hybrid_scores(q, k, jnp.int32(vl), jnp.float32(0.5),
                                     jnp.float32(0.02)))
        assert s[:vl].min() > s[vl:].max()

    def test_density_flags_duplicates(self):
        # a tight cluster of duplicate keys must have higher density than an
        # isolated outlier => coverage term (1-rho) prefers the outlier
        H, KV, hd, C = 2, 1, 8, 32
        key = jax.random.PRNGKey(7)
        base = rand(key, (1, KV, hd), 0.05)
        k = jnp.broadcast_to(base, (C, KV, hd))
        k = k.at[13].set(5.0)  # the outlier
        q = jnp.zeros((H, hd), jnp.float32)  # attention term ~uniform
        _, rho = hybrid_fields(q, k, jnp.int32(C), jnp.float32(0.05))
        rho = np.asarray(rho)
        assert rho[13] < rho[0], (rho[13], rho[0])
        s = np.asarray(hybrid_scores(q, k, jnp.int32(C), jnp.float32(0.0),
                                     jnp.float32(0.05)))
        assert s.argmax() == 13
