"""L2 model invariants: prefill/decode equivalence, RoPE virtual positions,
synapse selection properties, batch/single consistency, jnp-vs-Pallas path
agreement — on both random and trained weights."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import TINY, BOS_ID, PAD_ID

CFG = TINY
C = 64  # small capacity keeps interpret-mode tests fast


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def flat(params):
    return M.flatten_params(CFG, params)


def run_prefill(flat, toks, length, S=32, cap=C):
    return M.make_prefill(CFG, S, cap)(flat, toks, jnp.int32(length))


def seq_tokens(n, seed=3):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        np.concatenate([[BOS_ID], rng.integers(0, 256, n - 1)]).astype(np.int32)
    )


class TestPrefillDecodeEquivalence:
    @settings(max_examples=5, deadline=None)
    @given(length=st.integers(4, 30), seed=st.integers(0, 10_000))
    def test_stepwise_decode_matches_prefill(self, flat, length, seed):
        S = 32
        toks = jnp.pad(seq_tokens(length, seed), (0, S - length),
                       constant_values=PAD_ID)
        logits, hidden_last, kc, vc = run_prefill(flat, toks, length, S)

        decode = M.make_decode(CFG, C)
        kc2 = jnp.zeros((CFG.n_layers, C, CFG.n_kv_heads, CFG.head_dim))
        vc2 = jnp.zeros_like(kc2)
        for i in range(length):
            lg, hid, kn, vn = decode(flat, toks[i], jnp.int32(i), kc2, vc2,
                                     jnp.int32(i))
            kc2 = kc2.at[:, i].set(kn)
            vc2 = vc2.at[:, i].set(vn)
        np.testing.assert_allclose(lg, logits[length - 1], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(hid, hidden_last, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(kc2[:, :length], kc[:, :length],
                                   rtol=1e-5, atol=1e-5)

    def test_pallas_and_jnp_decode_agree(self, flat):
        length = 12
        toks = seq_tokens(length)
        kc = jnp.zeros((CFG.n_layers, C, CFG.n_kv_heads, CFG.head_dim))
        vc = jnp.zeros_like(kc)
        d_pallas = M.make_decode(CFG, C, use_pallas=True)
        d_jnp = M.make_decode(CFG, C, use_pallas=False)
        for i in range(length):
            a = d_pallas(flat, toks[i], jnp.int32(i), kc, vc, jnp.int32(i))
            b = d_jnp(flat, toks[i], jnp.int32(i), kc, vc, jnp.int32(i))
            for x, y in zip(a, b):
                np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-5)
            kc = kc.at[:, i].set(a[2])
            vc = vc.at[:, i].set(a[3])


class TestRoPEVirtualPositions:
    def test_inject_k_matches_decode_k_at_same_position(self, flat, params):
        """§3.6: a token encoded at virtual position p must produce the same
        K rows as the decode path writing at position p with an empty cache
        (both see no prior context)."""
        tok = 101
        p = 37
        inj = M.make_inject_encode(CFG, 4)
        ik, iv, _ = inj(flat, jnp.array([tok, 0, 0, 0], jnp.int32),
                        jnp.int32(1), jnp.int32(p))
        decode = M.make_decode(CFG, C)
        kc = jnp.zeros((CFG.n_layers, C, CFG.n_kv_heads, CFG.head_dim))
        _, _, kn, vn = decode(flat, jnp.int32(tok), jnp.int32(p), kc,
                              jnp.zeros_like(kc), jnp.int32(0))
        np.testing.assert_allclose(ik[:, 0], kn, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(iv[:, 0], vn, rtol=1e-5, atol=1e-6)

    def test_position_changes_keys_not_values(self, flat):
        """RoPE rotates K (position-dependent) but V is position-free."""
        inj = M.make_inject_encode(CFG, 4)
        toks = jnp.array([55, 0, 0, 0], jnp.int32)
        k1, v1, _ = inj(flat, toks, jnp.int32(1), jnp.int32(0))
        k2, v2, _ = inj(flat, toks, jnp.int32(1), jnp.int32(99))
        assert float(jnp.max(jnp.abs(k1[:, 0] - k2[:, 0]))) > 1e-4
        np.testing.assert_allclose(v1[:, 0], v2[:, 0], rtol=1e-6, atol=1e-7)


class TestSynapseExtract:
    K = 8

    def extract(self, flat, hidden, kc, vc, length, alpha=0.5):
        fn = M.make_synapse_extract(CFG, C, self.K)
        return fn(flat, hidden, kc, vc, jnp.int32(length),
                  jnp.float32(alpha), jnp.float32(1.0 / 64))

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 10_000), alpha=st.floats(0.0, 1.0))
    def test_indices_valid_unique_sorted(self, flat, seed, alpha):
        length = 24
        toks = seq_tokens(length, seed)
        S = 32
        padded = jnp.pad(toks, (0, S - length), constant_values=PAD_ID)
        _, hidden, kc, vc = run_prefill(flat, padded, length, S)
        lm_k, lm_v, idx, vals = self.extract(flat, hidden, kc, vc, length, alpha)
        idx = np.asarray(idx).astype(int)
        assert (idx >= 0).all() and (idx < length).all()
        assert len(set(idx.tolist())) == self.K
        assert (np.diff(idx) > 0).all(), "landmarks must stay in causal order"

    def test_gathered_rows_match_source(self, flat):
        length = 20
        S = 32
        padded = jnp.pad(seq_tokens(length), (0, S - length),
                         constant_values=PAD_ID)
        _, hidden, kc, vc = run_prefill(flat, padded, length, S)
        lm_k, lm_v, idx, _ = self.extract(flat, hidden, kc, vc, length)
        idx = np.asarray(idx).astype(int)
        np.testing.assert_allclose(lm_k, np.asarray(kc)[:, idx], rtol=1e-6)
        np.testing.assert_allclose(lm_v, np.asarray(vc)[:, idx], rtol=1e-6)

    def test_selected_scores_dominate_rest(self, flat):
        from compile.kernels.ref import hybrid_scores_ref
        length = 30
        S = 32
        padded = jnp.pad(seq_tokens(length, 9), (0, S - length),
                         constant_values=PAD_ID)
        _, hidden, kc, vc = run_prefill(flat, padded, length, S)
        _, _, idx, vals = self.extract(flat, hidden, kc, vc, length)
        # recompute all scores with the oracle, using the same query
        layer = M.pack_params(CFG, flat).layers[-1]
        q = (hidden @ layer.wq).reshape(CFG.n_heads, CFG.head_dim)
        cos, sin = M.rope_cos_sin(CFG, jnp.int32(length))
        q = M.apply_rope(q, cos[None, :], sin[None, :])
        scores = np.asarray(hybrid_scores_ref(
            q, kc[-1], jnp.int32(length), jnp.float32(0.5), jnp.float32(1.0 / 64)))
        chosen = set(np.asarray(idx).astype(int).tolist())
        rest = [s for i, s in enumerate(scores[:length]) if i not in chosen]
        assert min(float(v) for v in np.asarray(vals)) >= max(rest) - 1e-5


class TestBatchDecode:
    def test_batch_matches_single(self, flat):
        B = 2
        Cs = 32
        decode = M.make_decode(CFG, Cs)
        batch = M.make_decode_batch(CFG, B, Cs)
        kc = jnp.zeros((B, CFG.n_layers, Cs, CFG.n_kv_heads, CFG.head_dim))
        vc = jnp.zeros_like(kc)
        toks = jnp.array([70, 71], jnp.int32)
        pos = jnp.array([0, 0], jnp.int32)
        lens = jnp.array([0, 0], jnp.int32)
        blg, bh, bkn, bvn = batch(flat, toks, pos, kc, vc, lens)
        for i in range(B):
            lg, h, kn, vn = decode(flat, toks[i], pos[i], kc[i], vc[i], lens[i])
            np.testing.assert_allclose(blg[i], lg, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(bkn[i], kn, rtol=1e-6, atol=1e-6)


class TestTraining:
    def test_loss_decreases(self):
        from compile.train import train
        # 30 quick steps should reliably cut the loss well below ln(260)
        params = train(CFG, steps=30, seed=1, log_every=1000)
        from compile.corpus import build_corpus
        data = np.frombuffer(build_corpus(seed=7), dtype=np.uint8)
        toks = jnp.asarray(
            np.concatenate([[BOS_ID], data[:127]]).astype(np.int32))
        loss = float(M.lm_loss(CFG, params, toks, jnp.int32(128)))
        assert loss < 4.5, loss  # ln(260) ≈ 5.56 at random init


class TestParamABI:
    def test_spec_matches_flatten_roundtrip(self, params, flat):
        spec = M.param_spec(CFG)
        assert len(spec) == len(flat)
        for (name, shape), arr in zip(spec, flat):
            assert tuple(arr.shape) == shape, name
        packed = M.pack_params(CFG, flat)
        for a, b in zip(M.flatten_params(CFG, packed), flat):
            assert a is b

    def test_param_count_matches(self, flat):
        total = sum(int(np.prod(a.shape)) for a in flat)
        assert total == CFG.param_count()
